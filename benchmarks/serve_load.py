"""Serving load generator: N simulated users vs N sequential solo runs.

Drives the `repro.serve` continuous batcher with a deterministic load
(seeded prompts, fixed arrival schedule: user i submits after i
``--stagger`` decode ticks) against one resident compiled cell, then
replays the SAME prompts through the solo prefill+decode path the serve
layer must stay bit-identical to.  Reports:

  * aggregate decode throughput (tokens/s) for both paths and the
    batched/solo speedup — the paper's "weights never move" premise as
    a serving number: one ROM cell amortized across concurrent users;
  * per-request wall latency p50/p99 (queueing + decode) under the
    batched scheduler.

Prints CSV rows (``name,us_per_call,derived``) and doubles as the
``serve_load`` section of ``benchmarks.run --json`` — the decode-step
rows carry real wall time, so the CI gate (`benchmarks.compare`)
regression-checks the serve path like any kernel row.

  PYTHONPATH=src python -m benchmarks.serve_load [--fast] [--users 8]
      [--gen 16] [--slots 4] [--stagger 1]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _make_load(users: int, vocab: int, gen: int, seed: int = 0):
    """Deterministic per-user prompts: varied lengths, seeded content."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=8 + (i % 5), dtype=np.int64)
            for i in range(users)], [gen] * users


def simulate(model_id: str = "gemma-2b-smoke", *, users: int = 8,
             gen: int = 16, slots: int = 4, stagger: int = 1,
             max_len: int = 64, seed: int = 0) -> dict:
    """One batched run + one solo replay; returns the report dict."""
    from repro import serve

    model, _plan = serve.compile_entry(model_id)
    params = model.init(jax.random.PRNGKey(seed))
    prompts, gens = _make_load(users, model.cfg.vocab_size, gen, seed)

    # -- batched: continuous batching over one slot pool ---------------
    srv = serve.LMServer(model, params, n_slots=slots, max_len=max_len)
    # warm the two executables (prefill buckets by prompt length)
    for p in {p.size: p for p in prompts}.values():
        warm = srv.batcher._prefill(
            params, {"tokens": jnp.asarray(p[None])}, srv.pool.solo_cache())
        jax.block_until_ready(warm[0])
    warm_req = srv.submit(prompts[0], 2)
    srv.drain(max_steps=8)
    assert warm_req.done

    step0 = srv.batcher.step_count
    reqs = []
    t0 = time.perf_counter()
    tick = 0
    while len(reqs) < users or not srv.batcher.idle:
        # user i arrives after i*stagger ticks (deterministic schedule)
        while len(reqs) < users and len(reqs) * stagger <= tick:
            reqs.append(srv.submit(prompts[len(reqs)], gens[len(reqs)]))
        srv.step()
        tick += 1
        if tick > 100_000:
            raise RuntimeError("load loop stuck")
    wall_batched = time.perf_counter() - t0
    n_steps = srv.batcher.step_count - step0
    total_tokens = sum(len(r.tokens) for r in reqs)
    lats = sorted(r.latency_s for r in reqs)
    p50 = lats[len(lats) // 2]
    p99 = lats[min(len(lats) - 1, int(np.ceil(0.99 * len(lats))) - 1)]

    # -- solo replay: the baseline the batched path must beat ----------
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    # warm the solo wrappers too (every prompt-length bucket + decode):
    # both paths are timed with traces hot, so the speedup measures
    # scheduling, not compile caches
    for p in {p.size: p for p in prompts}.values():
        c = model.init_cache(1, max_len, dtype=jnp.float32)
        lg, c = prefill(params, {"tokens": jnp.asarray(p[None])}, c)
        lg, c = decode(params, jnp.asarray([[0]], jnp.int32), c)
        jax.block_until_ready(lg)
    solo_tokens = []
    t0 = time.perf_counter()
    for p, g in zip(prompts, gens):
        cache = model.init_cache(1, max_len, dtype=jnp.float32)
        logits, cache = prefill(params, {"tokens": jnp.asarray(p[None])},
                                cache)
        tok = int(jnp.argmax(logits[0, -1]))
        toks = [tok]
        for _ in range(g - 1):
            logits, cache = decode(
                params, jnp.asarray([[tok]], jnp.int32), cache)
            tok = int(jnp.argmax(logits[0, -1]))
            toks.append(tok)
        solo_tokens.append(toks)
    wall_solo = time.perf_counter() - t0

    bitwise = all(list(r.tokens) == s for r, s in zip(reqs, solo_tokens))
    return {
        "model_id": model_id, "users": users, "gen": gen, "slots": slots,
        "total_tokens": total_tokens, "decode_steps": n_steps,
        "wall_batched_s": wall_batched, "wall_solo_s": wall_solo,
        "tokens_s_batched": total_tokens / wall_batched,
        "tokens_s_solo": total_tokens / wall_solo,
        "speedup": wall_solo / wall_batched,
        "p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3,
        "bit_identical": bitwise,
    }


def report_lines(r: dict) -> list[str]:
    """CSV rows for benchmarks.run; wall_us rows feed the CI gate."""
    us_per_tok_b = r["wall_batched_s"] * 1e6 / r["total_tokens"]
    us_per_tok_s = r["wall_solo_s"] * 1e6 / r["total_tokens"]
    n = f"{r['users']}u{r['slots']}s"
    return [
        f"serve_us_per_token_batched_{n},{us_per_tok_b:.0f},"
        f"tokens_s={r['tokens_s_batched']:.1f} speedup="
        f"{r['speedup']:.2f}x bit_identical={r['bit_identical']}",
        f"serve_us_per_token_solo_{n},{us_per_tok_s:.0f},"
        f"tokens_s={r['tokens_s_solo']:.1f}",
        f"serve_latency_{n},0,p50_ms={r['p50_ms']:.1f} "
        f"p99_ms={r['p99_ms']:.1f} decode_steps={r['decode_steps']}",
    ]


def run() -> list[str]:
    """benchmarks.run section: the acceptance geometry (8 users over a
    4-slot pool) on the smoke LM.  bit_identical rides along in the
    derived column so a parity break is visible in every BENCH_*.json."""
    return report_lines(simulate(users=8, gen=16, slots=4))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small load (CI smoke): 4 users, 6 tokens")
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--stagger", type=int, default=1)
    ap.add_argument("--model", default="gemma-2b-smoke")
    args = ap.parse_args(argv)
    if args.fast:
        args.users, args.gen = min(args.users, 4), min(args.gen, 6)
    r = simulate(args.model, users=args.users, gen=args.gen,
                 slots=args.slots, stagger=args.stagger)
    print("name,us_per_call,derived")
    for line in report_lines(r):
        print(line)
    if not r["bit_identical"]:
        print("FAIL: batched serve output diverged from the solo path")
        return 1
    if r["speedup"] <= 1.0:
        print(f"WARN: batched serving not faster than solo "
              f"({r['speedup']:.2f}x) at users={args.users}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
