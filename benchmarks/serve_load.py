"""Serving load generator: paged vs dense pools under mixed-length load.

Drives the `repro.serve` continuous batcher with a deterministic
mixed-prompt-length load (seeded content, lengths spread over
[--prompt-min, --prompt-max], fixed arrival schedule: user i submits
after i ``--stagger`` decode ticks) against one resident compiled cell,
TWICE — once over the dense ``SlotPool`` and once over a ``PagedPool``
carved from the SAME byte budget — then replays the SAME prompts
through the solo prefill+decode path both pools must stay bit-identical
to.  Reports:

  * aggregate decode throughput (tokens/s) for all three paths and the
    paged/dense/solo ratios — the paper's "weights never move" premise
    as a serving number: one ROM cell amortized across concurrent
    users, and the plan-budgeted KV bytes amortized across mixed
    request lengths;
  * per-request wall latency p50/p99 with each request's PROMPT LENGTH
    alongside, so the mixed-length distribution is visible in the
    ``BENCH_*.json`` record;
  * pool utilization / fragmentation: live KV tokens over committed
    capacity (granted blocks for paged, whole occupied rows for dense)
    sampled every decode tick — the number paging exists to raise.

Prints CSV rows (``name,us_per_call,derived``) and doubles as the
``serve_load`` section of ``benchmarks.run --json``, so the CI gate
(`benchmarks.compare`) regression-checks the serve path like any
kernel row.

  PYTHONPATH=src python -m benchmarks.serve_load [--fast] [--users 8]
      [--gen 16] [--slots 4] [--stagger 1] [--prompt-min 8]
      [--prompt-max 128]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _make_load(users: int, vocab: int, gen: int, seed: int = 0,
               prompt_min: int = 8, prompt_max: int = 128):
    """Deterministic per-user prompts: lengths spread evenly over
    [prompt_min, prompt_max], shuffled, seeded content."""
    rng = np.random.default_rng(seed)
    lens = np.linspace(prompt_min, prompt_max, users).astype(int)
    rng.shuffle(lens)
    return [rng.integers(0, vocab, size=int(n), dtype=np.int64)
            for n in lens], [gen] * users


def _solo_replay(model, params, prompts, gens, max_len: int) -> dict:
    """The baseline every pool must match bitwise: sequential batch=1
    prefill + decode per prompt (traces warmed first, so the timed pass
    measures execution, not compile caches)."""
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    for p in {p.size: p for p in prompts}.values():
        c = model.init_cache(1, max_len, dtype=jnp.float32)
        lg, c = prefill(params, {"tokens": jnp.asarray(p[None])}, c)
        lg, c = decode(params, jnp.asarray([[0]], jnp.int32), c)
        jax.block_until_ready(lg)
    tokens = []
    t0 = time.perf_counter()
    for p, g in zip(prompts, gens):
        cache = model.init_cache(1, max_len, dtype=jnp.float32)
        logits, cache = prefill(params, {"tokens": jnp.asarray(p[None])},
                                cache)
        tok = int(jnp.argmax(logits[0, -1]))
        toks = [tok]
        for _ in range(g - 1):
            logits, cache = decode(
                params, jnp.asarray([[tok]], jnp.int32), cache)
            tok = int(jnp.argmax(logits[0, -1]))
            toks.append(tok)
        tokens.append(toks)
    return {"tokens": tokens, "wall_s": time.perf_counter() - t0}


def _race(srv, prompts, gens, stagger: int):
    """Submit the load on its arrival schedule and drain; returns
    (requests, wall_s, decode_steps, mean_utilization, peak_active)."""
    batcher = srv.batcher
    step0 = batcher.step_count
    reqs, util, peak = [], [], 0
    t0 = time.perf_counter()
    tick = 0
    while len(reqs) < len(prompts) or not batcher.idle:
        while len(reqs) < len(prompts) and len(reqs) * stagger <= tick:
            i = len(reqs)
            reqs.append(srv.submit(prompts[i], gens[i]))
        srv.step()
        # live KV tokens over committed capacity: granted blocks for
        # the paged pool, whole occupied rows for the dense one
        live = sum(r.prompt.size + len(r.tokens)
                   for r in batcher._active.values())
        pool = srv.pool
        committed = (pool.blocks_in_use * pool.block_size
                     if hasattr(pool, "blocks_in_use")
                     else pool.occupancy * pool.max_len)
        if committed:
            util.append(min(1.0, live / committed))
        peak = max(peak, batcher.active)
        tick += 1
        if tick > 100_000:
            raise RuntimeError("load loop stuck")
    wall = time.perf_counter() - t0
    return (reqs, wall, batcher.step_count - step0,
            float(np.mean(util)) if util else 0.0, peak)


def simulate(model_id: str = "gemma-2b-smoke", *, users: int = 8,
             gen: int = 16, slots: int = 4, stagger: int = 1,
             max_len: int = 160, seed: int = 0, paged: bool = False,
             prompt_min: int = 8, prompt_max: int = 128,
             block_size: int = 16, prefill_chunk: int | None = None,
             solo: dict | None = None) -> dict:
    """One batched run + one solo replay; returns the report dict.

    ``paged=True`` serves the same load through a :class:`PagedPool`
    sized to the SAME byte budget as ``slots`` dense rows
    (``slots * max_len / block_size`` blocks) but twice the batch rows,
    so the fragmentation win shows up as admitted concurrency.  Pass
    ``solo=`` (a previous run's ``["solo"]``) to skip re-timing the
    solo replay when racing both pools over one load.
    """
    from repro import serve

    model, _plan = serve.compile_entry(model_id)
    params = model.init(jax.random.PRNGKey(seed))
    prompts, gens = _make_load(users, model.cfg.vocab_size, gen, seed,
                               prompt_min, prompt_max)
    for p in prompts:
        if p.size + gen > max_len:
            raise ValueError(
                f"prompt {p.size} + gen {gen} exceeds max_len {max_len}")

    if paged:
        rows = 2 * slots
        n_blocks = slots * (max_len // block_size)
        srv = serve.LMServer(model, params, n_slots=rows, max_len=max_len,
                             paged=True, block_size=block_size,
                             n_blocks=n_blocks,
                             prefill_chunk=prefill_chunk)
    else:
        rows, n_blocks = slots, 0
        srv = serve.LMServer(model, params, n_slots=slots, max_len=max_len,
                             paged=False, prefill_chunk=prefill_chunk)

    # warm pass: the same load once through (compiles every prefill
    # bucket — including chunked-prefill shapes — and the decode step),
    # so the timed race below measures scheduling, not compile caches
    _race(srv, prompts, gens, stagger)
    reqs, wall_b, n_steps, mean_util, peak = _race(srv, prompts, gens,
                                                   stagger)
    total_tokens = sum(len(r.tokens) for r in reqs)
    lats = sorted(r.latency_s for r in reqs)
    p50 = lats[len(lats) // 2]
    p99 = lats[min(len(lats) - 1, int(np.ceil(0.99 * len(lats))) - 1)]

    if solo is None:
        solo = _solo_replay(model, params, prompts, gens, max_len)
    bitwise = all(list(r.tokens) == s
                  for r, s in zip(reqs, solo["tokens"]))
    return {
        "model_id": model_id, "users": users, "gen": gen,
        "paged": paged, "rows": rows, "slots": slots,
        "n_blocks": n_blocks, "block_size": block_size if paged else 0,
        "total_tokens": total_tokens, "decode_steps": n_steps,
        "wall_batched_s": wall_b, "wall_solo_s": solo["wall_s"],
        "tokens_s_batched": total_tokens / wall_b,
        "tokens_s_solo": total_tokens / solo["wall_s"],
        "speedup": solo["wall_s"] / wall_b,
        "p50_ms": p50 * 1e3, "p99_ms": p99 * 1e3,
        "mean_utilization": mean_util,
        "fragmentation": 1.0 - mean_util,
        "peak_active": peak,
        "per_request": [
            {"prompt_len": int(r.prompt.size),
             "latency_ms": r.latency_s * 1e3} for r in reqs],
        "bit_identical": bitwise,
        "solo": solo,
    }


def report_lines(r: dict, tag: str) -> list[str]:
    """CSV rows for benchmarks.run; wall_us rows feed the CI gate.

    The latency row carries every request's prompt length alongside
    p50/p99 (``len:latency`` pairs), so the mixed-length distribution
    is recorded in BENCH_*.json, not just its aggregates.
    """
    us_per_tok = r["wall_batched_s"] * 1e6 / r["total_tokens"]
    n = f"{r['users']}u"
    per_req = "|".join(f"{d['prompt_len']}:{d['latency_ms']:.0f}ms"
                       for d in r["per_request"])
    return [
        f"serve_us_per_token_{tag}_{n},{us_per_tok:.0f},"
        f"tokens_s={r['tokens_s_batched']:.1f} speedup="
        f"{r['speedup']:.2f}x bit_identical={r['bit_identical']}",
        f"serve_latency_{tag}_{n},0,p50_ms={r['p50_ms']:.1f} "
        f"p99_ms={r['p99_ms']:.1f} decode_steps={r['decode_steps']} "
        f"prompt_ms={per_req}",
        f"serve_pool_{tag}_{n},0,utilization="
        f"{r['mean_utilization']:.3f} fragmentation="
        f"{r['fragmentation']:.3f} peak_active={r['peak_active']} "
        f"rows={r['rows']}",
    ]


def run() -> list[str]:
    """benchmarks.run section: the acceptance geometry — 8 users at
    mixed prompt lengths 8..128 over (a) a 4-slot dense pool and (b) a
    paged pool of the same byte budget — plus the solo reference row.
    bit_identical rides along in the derived column so a parity break
    is visible in every BENCH_*.json."""
    dense = simulate(users=8, gen=16, slots=4, paged=False)
    paged = simulate(users=8, gen=16, slots=4, paged=True,
                     solo=dense["solo"])
    us_solo = dense["wall_solo_s"] * 1e6 / dense["total_tokens"]
    return (report_lines(dense, "dense")
            + report_lines(paged, "paged")
            + [f"serve_us_per_token_solo_8u,{us_solo:.0f},"
               f"tokens_s={dense['tokens_s_solo']:.1f}",
               f"serve_paged_vs_dense_8u,0,tokens_s_ratio="
               f"{paged['tokens_s_batched'] / dense['tokens_s_batched']:.2f}"
               f" util_ratio={paged['mean_utilization'] / max(1e-9, dense['mean_utilization']):.2f}"
               f" peak_active={paged['peak_active']}v{dense['peak_active']}"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small load (CI smoke): 4 users, 6 tokens, "
                         "prompts to 64")
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--stagger", type=int, default=1)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=128)
    ap.add_argument("--max-len", type=int, default=160)
    ap.add_argument("--model", default="gemma-2b-smoke")
    args = ap.parse_args(argv)
    if args.fast:
        args.users, args.gen = min(args.users, 4), min(args.gen, 6)
        args.prompt_max = min(args.prompt_max, 64)
        args.max_len = min(args.max_len, 96)
    kw = dict(users=args.users, gen=args.gen, slots=args.slots,
              stagger=args.stagger, prompt_min=args.prompt_min,
              prompt_max=args.prompt_max, max_len=args.max_len)
    dense = simulate(args.model, paged=False, **kw)
    paged = simulate(args.model, paged=True, solo=dense["solo"], **kw)
    print("name,us_per_call,derived")
    for line in (report_lines(dense, "dense")
                 + report_lines(paged, "paged")):
        print(line)
    ok = True
    for r, tag in ((dense, "dense"), (paged, "paged")):
        if not r["bit_identical"]:
            print(f"FAIL: {tag} serve output diverged from the solo path")
            ok = False
    if paged["peak_active"] < dense["peak_active"] or \
            paged["mean_utilization"] < dense["mean_utilization"] * 0.5:
        print("WARN: paged pool shows no occupancy/utilization win "
              "over dense at this load")
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
