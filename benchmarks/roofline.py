"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)
with TPU v5e-class constants.  cost_analysis() reports whole-program
totals, so each term is divided by the device count; collective bytes are
parsed from the per-device partitioned HLO (already per-device).

Also reports MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) against
HLO FLOPs (useful-compute fraction: catches remat/redundancy waste —
NOTE: with ReBranch, trunk dW is intentionally skipped, so the *ideal*
train FLOPs are ~(2/3 + 1/(3*16)) of the 6ND convention; both numbers
are reported) and the dominant bottleneck per cell.
"""

from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per chip, ring neighbour)


def model_params_and_active(arch: str) -> tuple[float, float]:
    from repro import configs, deploy
    import jax
    cfg = configs.get(arch)
    shapes = jax.eval_shape(deploy.compile_model(cfg).init,
                            jax.random.PRNGKey(0))
    total = sum(l.size for l in jax.tree.leaves(shapes))
    if cfg.family == "moe":
        # active = non-expert params + activated experts (+shared)
        leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
        expert = sum(l.size for p, l in leaves
                     if "experts" in jax.tree_util.keystr(p))
        frac = cfg.num_experts_per_tok / cfg.num_experts
        active = (total - expert) + expert * frac
        return float(total), float(active)
    return float(total), float(total)


def roofline_terms(rec: dict) -> dict:
    # all inputs are PER-DEVICE (parsed from the partitioned HLO module)
    flops = rec["flops"]
    t_compute = flops / PEAK_FLOPS
    t_memory = rec["hbm_bytes"] / HBM_BW
    t_coll = rec["collective_bytes"] / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    out = dict(rec)
    out.update(t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
               dominant=dominant,
               bound=max(t_compute, t_memory, t_coll),
               roofline_frac=t_compute / max(t_compute, t_memory, t_coll,
                                             1e-30))
    return out


def analyse(results_path: str = "dryrun_results.json") -> list[dict]:
    with open(results_path) as f:
        records = json.load(f)
    out = []
    cache: dict[str, tuple[float, float]] = {}
    for rec in records:
        r = roofline_terms(rec)
        arch = rec["arch"]
        if rec["kind"] == "cnn_serve":
            # CNN cells: no 6ND token convention — roofline terms only
            r["model_flops"] = None
            r["useful_frac"] = float("nan")
            out.append(r)
            continue
        if arch not in cache:
            cache[arch] = model_params_and_active(arch)
        n_total, n_active = cache[arch]
        tokens = rec["global_batch"] * (rec["seq"] if rec["kind"] != "decode"
                                        else 1)
        if rec["kind"] == "train":
            model_flops = 6.0 * n_active * tokens
        else:
            model_flops = 2.0 * n_active * tokens
        r["model_flops"] = model_flops
        # flops are per-device; model_flops is global
        r["useful_frac"] = (model_flops / rec["devices"]
                            / max(rec["flops"], 1e-30))
        out.append(r)
    return out


def run() -> list[str]:
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_results.json")
    if not os.path.exists(path):
        return ["roofline,0,SKIPPED (run repro.launch.dryrun --out "
                "dryrun_results.json first)"]
    lines = []
    for r in analyse(path):
        name = f"{r['arch']}/{r['shape']}/{r.get('mesh_name', r['mesh'])}"
        lines.append(
            f"roofline_{name},0,"
            f"tc={r['t_compute']*1e3:.3f}ms tm={r['t_memory']*1e3:.3f}ms "
            f"tcoll={r['t_collective']*1e3:.3f}ms dom={r['dominant']} "
            f"frac={r['roofline_frac']:.3f} useful={r['useful_frac']:.3f}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
