"""Fig. 10: ReBranch generalization — transfer accuracy vs the all-SRAM
full-fine-tune baseline, plus the area saving.

Paper claims: <0.4% accuracy loss in classification with ~10x memory-area
saving.  Here: synthetic task-A -> task-B transfer on the (reduced) VGG-8;
the tested claim is the ReBranch-vs-full-fine-tune accuracy GAP and the
frozen-trunk floor it recovers from, plus the area ratio from the cost
model on the real VGG-8/ResNet-18 stats.
"""

from __future__ import annotations

import time

from benchmarks import netstats, transfer_harness as th
from repro.core import energy


def run() -> list[str]:
    lines = []
    t0 = time.time()
    _, acc_a = th.pretrained_dense()
    acc_full, _ = th.run_transfer("full")
    acc_rb, frac_rb = th.run_transfer("rebranch")
    acc_frozen, _ = th.run_transfer("frozen")
    us = (time.time() - t0) * 1e6

    gap = acc_full - acc_rb
    recovered = (acc_rb - acc_frozen) / max(acc_full - acc_frozen, 1e-9)
    lines.append(f"fig10_pretrain_acc_taskA,{us:.0f},{acc_a:.4f}")
    lines.append(f"fig10_full_finetune_acc,{us:.0f},{acc_full:.4f}")
    lines.append(f"fig10_rebranch_acc,{us:.0f},{acc_rb:.4f}")
    lines.append(f"fig10_frozen_trunk_acc,{us:.0f},{acc_frozen:.4f}")
    lines.append(f"fig10_acc_gap_vs_full,{us:.0f},{gap:.4f} "
                 f"(paper <0.004 at full scale)")
    lines.append(f"fig10_gap_recovered_frac,{us:.0f},{recovered:.3f}")
    lines.append(f"fig10_trainable_frac,{us:.0f},{frac_rb:.4f}")

    for name in ("vgg8", "resnet18"):
        ns = netstats.paper_net_stats()[name]
        ratio = energy.area_ratio(ns)
        lines.append(f"fig10_area_saving_{name},{us:.0f},{ratio:.2f}x "
                     f"(paper ~10x)")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
