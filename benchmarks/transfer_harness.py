"""Shared harness for the ReBranch transfer-learning experiments
(Figs. 10-12): pretrain a CNN on synthetic task A, tape-out to ROM,
transfer to task B under different adaptation schemes."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import rebranch
from repro.core.rebranch import ReBranchSpec
from repro.data import synthetic
from repro.models import cnn


@dataclasses.dataclass(frozen=True)
class TransferConfig:
    input_size: int = 16
    num_classes: int = 10
    batch: int = 32
    pretrain_steps: int = 220
    finetune_steps: int = 220
    eval_batches: int = 10
    lr: float = 2e-3
    seed_a: int = 100           # task A (pretraining distribution)
    seed_b: int = 200           # task B (transfer target)


def small_vgg_cfg(spec: ReBranchSpec, tc: TransferConfig):
    return cnn.CNNConfig(name="vgg8", num_classes=tc.num_classes,
                         input_size=tc.input_size, rebranch=spec)


def _loss(params, x, y, cfg):
    logits = cnn.apply_vgg8(params, x, cfg)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def _accuracy(params, cfg, tc, seed):
    correct = total = 0
    for i in range(tc.eval_batches):
        x, y = synthetic.image_batch(seed, 10_000 + i, tc.batch,
                                     tc.input_size, tc.num_classes)
        pred = jnp.argmax(cnn.apply_vgg8(params, x, cfg), axis=-1)
        correct += int(jnp.sum(pred == y))
        total += tc.batch
    return correct / total


def _train(params, cfg, tc, seed, steps, lr=None):
    trainable, frozen = rebranch.partition(params)
    opt = optim.init(trainable)
    ocfg = optim.AdamWConfig(lr=lr or tc.lr, weight_decay=0.0)

    @jax.jit
    def step_fn(t, opt, x, y):
        loss, g = jax.value_and_grad(
            lambda tt: _loss(rebranch.combine(tt, frozen), x, y, cfg))(t)
        t, opt, _ = optim.update(g, opt, t, ocfg)
        return t, opt, loss

    for s in range(steps):
        x, y = synthetic.image_batch(seed, s, tc.batch, tc.input_size,
                                     tc.num_classes)
        trainable, opt, loss = step_fn(trainable, opt, x, y)
    return rebranch.combine(trainable, frozen)


@functools.lru_cache(maxsize=4)
def pretrained_dense(tc: TransferConfig = TransferConfig()):
    """Task-A pretrained all-trainable model (cached across figures)."""
    spec = ReBranchSpec(enabled=False)
    cfg = small_vgg_cfg(spec, tc)
    params = cnn.init_vgg8(jax.random.PRNGKey(0), cfg)
    params = _train(params, cfg, tc, tc.seed_a, tc.pretrain_steps)
    acc_a = _accuracy(params, cfg, tc, tc.seed_a)
    return params, acc_a


def run_transfer(scheme: str, tc: TransferConfig = TransferConfig(),
                 d_ratio: int = 4, u_ratio: int = 4):
    """scheme: 'rebranch' | 'full' | 'frozen' -> (acc_b, trainable_frac)."""
    dense, _ = pretrained_dense(tc)
    if scheme == "full":                 # all-SRAM upper bound
        spec = ReBranchSpec(enabled=False)
        cfg = small_vgg_cfg(spec, tc)
        p = jax.tree.map(lambda x: x, dense)
        p = _train(p, cfg, tc, tc.seed_b, tc.finetune_steps)
        return _accuracy(p, cfg, tc, tc.seed_b), 1.0
    spec = ReBranchSpec(d_ratio=d_ratio, u_ratio=u_ratio,
                        branch_enabled=(scheme == "rebranch"))
    cfg = small_vgg_cfg(spec, tc)
    p = cnn.freeze_to_rom(dense, jax.random.PRNGKey(7), spec)
    if scheme == "rebranch":
        p = _train(p, cfg, tc, tc.seed_b, tc.finetune_steps)
    else:                                # 'frozen': head-only adaptation
        p = _train(p, cfg, tc, tc.seed_b, tc.finetune_steps)
    acc = _accuracy(p, cfg, tc, tc.seed_b)
    n_t = rebranch.trainable_count(p)
    n_f = rebranch.frozen_count(p)
    return acc, n_t / (n_t + n_f)
