"""Re-derive per-cell costs from saved partitioned HLO (hlo/*.hlo.gz)
without recompiling: merges hlo_cost numbers into dryrun_results.json
records (memory fields come from the original compile).

  PYTHONPATH=src python -m benchmarks.reanalyse \
      --hlo-dir hlo --base dryrun_results.json --out dryrun_results.json
"""

from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.launch import hlo_cost


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo-dir", default="hlo")
    ap.add_argument("--base", default="dryrun_results.json")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    with open(args.base) as f:
        records = json.load(f)
    by_key = {}
    for r in records:
        by_key[(r["arch"], r["shape"], r["mesh"])] = r

    n = 0
    for path in sorted(glob.glob(os.path.join(args.hlo_dir, "*.hlo.gz"))):
        stem = os.path.basename(path)[:-7]
        parts = stem.rsplit("_", 2)
        # <arch>_<shape>_<mesh>: shape contains one '_', mesh has 'x'
        arch_shape, mesh = stem.rsplit("_", 1)
        arch, shape = None, None
        for cand in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if arch_shape.endswith("_" + cand):
                arch = arch_shape[: -len(cand) - 1]
                shape = cand
                break
        if arch is None:
            continue
        key = (arch, shape, mesh)
        rec = by_key.get(key)
        if rec is None:
            continue
        with gzip.open(path, "rt") as f:
            costs = hlo_cost.analyse_text(f.read())
        rec.update(flops=costs["flops"], hbm_bytes=costs["hbm_bytes"],
                   collective_bytes=costs["collective_bytes"],
                   collectives=costs["collectives"])
        n += 1
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"reanalysed {n} cells -> {args.out}")


if __name__ == "__main__":
    main()
