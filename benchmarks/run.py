"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Usage:
    PYTHONPATH=src python -m benchmarks.run [--fast]
--fast skips the training-based figures (10/11), keeping the analytic
tables and the roofline report.
"""

import sys


def main() -> None:
    fast = "--fast" in sys.argv
    from benchmarks import (table1_macro, fig12_area_map,
                            fig14_system_energy, conv_kernel, roofline)
    sections = [table1_macro, fig12_area_map, fig14_system_energy,
                conv_kernel]
    if not fast:
        from benchmarks import fig10_generalization, fig11_du_sweep
        sections[1:1] = [fig10_generalization, fig11_du_sweep]
    sections.append(roofline)
    print("name,us_per_call,derived")
    for mod in sections:
        for line in mod.run():
            print(line, flush=True)


if __name__ == "__main__":
    main()
