"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines, and with ``--json OUT``
also writes machine-readable records (section / metric / value / unit /
wall_us / derived) for the CI benchmark-tracking gate
(``benchmarks.compare``) and the checked-in ``BENCH_*.json`` trajectory
points at the repo root.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--fast] [--json out.json]
--fast skips the training-based figures (10/11), keeping the analytic
tables and the roofline report.
"""

import argparse
import json


def _sections(fast: bool) -> list:
    from benchmarks import (table1_macro, fig12_area_map,
                            fig14_system_energy, conv_kernel, placement,
                            roofline, scenario_swap, serve_load,
                            spec_decode, tuned_kernel)
    sections = [table1_macro, fig12_area_map, fig14_system_energy,
                placement, conv_kernel, tuned_kernel, serve_load,
                scenario_swap, spec_decode]
    if not fast:
        from benchmarks import fig10_generalization, fig11_du_sweep
        sections[1:1] = [fig10_generalization, fig11_du_sweep]
    sections.append(roofline)
    return sections


def parse_line(section: str, line: str) -> dict:
    """One ``name,us_per_call,derived`` CSV line -> a benchmark record."""
    name, us, derived = line.split(",", 2)
    return {"section": section, "metric": name, "value": float(us),
            "unit": "us_per_call", "wall_us": float(us), "derived": derived}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the training-based figures (10/11)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write records as JSON (CI bench tracking)")
    args = ap.parse_args(argv)

    records = []
    print("name,us_per_call,derived")
    for mod in _sections(args.fast):
        section = mod.__name__.rsplit(".", 1)[-1]
        for line in mod.run():
            print(line, flush=True)
            if args.json:
                records.append(parse_line(section, line))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}")


if __name__ == "__main__":
    main()
