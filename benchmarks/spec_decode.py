"""Speculative decode race: branch-draft + batched verify vs plain decode.

Drives the ``repro.serve`` continuous batcher over one resident compiled
cell in speculative mode (``spec_k > 0``: up to k tokens per row drafted
by the branch-only model — ROM trunks skipped — then ONE batched
``verify_step`` through the full trunk+branch cell per round) and races
it against the same load with speculation off.  Because acceptance rate
is the whole story for speculative decode, the benchmark sweeps it
deterministically: an ORACLE draft source proposes the known greedy
continuation with probability alpha per position (seeded per request and
position), so the acceptance axis is dialed, not hoped for; one row also
runs the real branch drafter, whose acceptance is a measured property of
the ReBranch approximation itself.

Reported per configuration:

  * aggregate decode tokens/s and the spec-on/spec-off ratio — the
    headline: at high acceptance, k tokens land per full-cell dispatch
    instead of one;
  * per-request tokens/s (p50 over requests) alongside the aggregate,
    so batching effects and speculation effects stay distinguishable;
  * acceptance rate (accepted / verified draft tokens) and verify
    rounds vs plain decode steps;
  * drafted-vs-verified FLOP ratio from the placement plan's MAC stats
    ((branch + sram MACs) / total MACs — the ~1/16 asymmetry that makes
    the branch a nearly-free drafter);
  * two hard invariants, each exit-1 on violation: every configuration's
    output is BIT-IDENTICAL to the non-speculative greedy decode of the
    same prompts, and the paged pool's block accounting drains to zero
    (granted + reserved == 0) after every speculative run — rejected
    drafts must never leak blocks.

Prints CSV rows (``name,us_per_call,derived``) and doubles as the
``spec_decode`` section of ``benchmarks.run --json``.  Ratio/acceptance
rows carry 0 in the us field and names the CI gate recognises as
dimensionless (``benchmarks.compare.is_ratio_metric``).

  PYTHONPATH=src python -m benchmarks.spec_decode [--fast] [--users 6]
      [--gen 24] [--spec-k 4] [--alphas 0.6 0.95]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _make_load(users: int, vocab: int, gen: int, seed: int = 0,
               prompt_min: int = 6, prompt_max: int = 24):
    """Deterministic mixed-length prompts (seeded content)."""
    rng = np.random.default_rng(seed)
    lens = np.linspace(prompt_min, prompt_max, users).astype(int)
    rng.shuffle(lens)
    return [rng.integers(1, vocab, size=int(n), dtype=np.int64)
            for n in lens], [gen] * users


def _solo_greedy(model, params, prompts, gens, max_len: int) -> list:
    """The greedy continuation per prompt — the bit-parity reference
    AND the oracle drafter's answer sheet."""
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    out = []
    for p, g in zip(prompts, gens):
        cache = model.init_cache(1, max_len, dtype=jnp.float32)
        logits, cache = prefill(params, {"tokens": jnp.asarray(p[None])},
                                cache)
        tok = int(jnp.argmax(logits[0, -1]))
        toks = [tok]
        for _ in range(g - 1):
            logits, cache = decode(
                params, jnp.asarray([[tok]], jnp.int32), cache)
            tok = int(jnp.argmax(logits[0, -1]))
            toks.append(tok)
        out.append(toks)
    return out


def _oracle(refs: list, vocab: int, alpha: float, seed: int = 0):
    """A draft source proposing the known greedy continuation with
    probability ``alpha`` per position (else a deliberately wrong
    token), seeded per (request, position): the acceptance rate is a
    dial, and reruns are deterministic.  Greedy accept-longest-prefix
    cuts the round at the first wrong draft, so the EXPECTED accepted
    run per round is the geometric partial sum of alpha."""
    coins = [np.random.default_rng((seed, rid)).random(len(ref))
             for rid, ref in enumerate(refs)]

    def draft(active, last_tok, k):
        drafts = np.zeros((last_tok.shape[0], k), np.int32)
        for slot, req in active.items():
            # rids run on across races of the same load (warm pass then
            # timed pass); submission order maps them back to prompts
            idx = req.rid % len(refs)
            ref, coin = refs[idx], coins[idx]
            pos = len(req.tokens)
            for i in range(k):
                true_tok = ref[pos + i]
                drafts[slot, i] = true_tok if coin[pos + i] < alpha \
                    else (true_tok + 1) % vocab
        return drafts

    return draft


def _race(srv, prompts, gens):
    """Submit everything, drain, time.  Returns (requests, wall_s)."""
    t0 = time.perf_counter()
    reqs = [srv.submit(p, g) for p, g in zip(prompts, gens)]
    srv.drain(max_steps=200_000)
    return reqs, time.perf_counter() - t0


def simulate(model_id: str = "gemma-2b-smoke", *, users: int = 6,
             gen: int = 24, slots: int = 4, spec_k: int = 4,
             alpha: float | None = None, draft: str = "oracle",
             paged: bool = True, max_len: int = 64, block_size: int = 8,
             seed: int = 0, shared: dict | None = None) -> dict:
    """One speculative (or plain, ``spec_k=0``) serving run.

    draft='oracle' uses the alpha-dialed oracle draft source (requires
    ``alpha``); draft='branch' runs the real branch-only draft model.
    ``shared`` carries (model, params, prompts, gens, solo tokens)
    across configurations so every run races the identical load on the
    identical cell.
    """
    from repro import serve

    if shared is None:
        model, plan = serve.compile_entry(model_id)
        params = model.init(jax.random.PRNGKey(seed))
        prompts, gens = _make_load(users, model.cfg.vocab_size, gen, seed)
        for p in prompts:
            if p.size + gen > max_len:
                raise ValueError(f"prompt {p.size} + gen {gen} exceeds "
                                 f"max_len {max_len}")
        solo = _solo_greedy(model, params, prompts, gens, max_len)
        shared = {"model": model, "plan": plan, "params": params,
                  "prompts": prompts, "gens": gens, "solo": solo}
    model, params = shared["model"], shared["params"]
    prompts, gens, solo = shared["prompts"], shared["gens"], shared["solo"]

    draft_source = None
    if spec_k and draft == "oracle":
        if alpha is None:
            raise ValueError("draft='oracle' needs alpha")
        draft_source = _oracle(solo, model.cfg.vocab_size, alpha, seed)

    srv = serve.LMServer(
        model, params, n_slots=slots, max_len=max_len, paged=paged,
        block_size=block_size if paged else None,
        spec_k=spec_k, draft_source=draft_source)
    # warm pass on the SAME server (its jit wrappers hold the trace
    # caches): the load drains completely, so the pool is clean and the
    # timed pass measures scheduling + execution, not compilation
    _race(srv, prompts, gens)
    b = srv.batcher
    steps0, rounds0 = b.step_count, b.spec_rounds
    drafted0, matched0 = b.drafted_total, b.matched_total
    reqs, wall = _race(srv, prompts, gens)

    total = sum(len(r.tokens) for r in reqs)
    per_req = sorted(len(r.tokens) / max(r.latency_s, 1e-9) for r in reqs)
    leak = 0
    if paged:
        leak = srv.pool.blocks_in_use + srv.pool.blocks_reserved
    return {
        "spec_k": spec_k, "draft": draft if spec_k else "off",
        "alpha": alpha, "users": users, "gen": gen, "paged": paged,
        "total_tokens": total, "wall_s": wall,
        "tokens_s": total / wall,
        "tokens_s_p50_request": per_req[len(per_req) // 2],
        "steps": b.step_count - steps0,
        "spec_rounds": b.spec_rounds - rounds0,
        "drafted": b.drafted_total - drafted0,
        "acceptance": ((b.matched_total - matched0)
                       / max(1, b.drafted_total - drafted0)
                       if spec_k else 0.0),
        "bit_identical": all(list(r.tokens) == s
                             for r, s in zip(reqs, solo)),
        "leaked_blocks": leak,
        "shared": shared,
    }


def flop_ratio(shared: dict) -> float:
    """(branch + SRAM MACs) / total MACs per token under the resident
    plan — what one draft token costs relative to one verify token."""
    plan = shared.get("plan")
    if plan is None:
        return float("nan")
    stats = plan.stats(shared["model"].cfg)
    return (stats.branch_macs + stats.sram_macs) / max(1, stats.total_macs)


def report_lines(results: list, base: dict, shared: dict) -> list[str]:
    """CSV rows for benchmarks.run.  Wall-us rows feed the CI latency
    gate; ratio/acceptance rows carry 0 us and ratio-marked names."""
    lines = [
        f"spec_us_per_token_off,"
        f"{base['wall_s'] * 1e6 / base['total_tokens']:.0f},"
        f"tokens_s={base['tokens_s']:.1f} "
        f"p50_req_tokens_s={base['tokens_s_p50_request']:.1f} "
        f"steps={base['steps']} bit_identical={base['bit_identical']}",
    ]
    for r in results:
        tag = (f"{r['draft']}_a{int(r['alpha'] * 100)}"
               if r["draft"] == "oracle" else r["draft"])
        lines += [
            f"spec_us_per_token_{tag},"
            f"{r['wall_s'] * 1e6 / r['total_tokens']:.0f},"
            f"tokens_s={r['tokens_s']:.1f} "
            f"p50_req_tokens_s={r['tokens_s_p50_request']:.1f} "
            f"rounds={r['spec_rounds']} k={r['spec_k']} "
            f"bit_identical={r['bit_identical']} "
            f"leaked_blocks={r['leaked_blocks']}",
            f"spec_acceptance_{tag},0,"
            f"acceptance={r['acceptance']:.3f} drafted={r['drafted']}",
            f"spec_speedup_ratio_{tag},0,"
            f"tokens_s_ratio={r['tokens_s'] / base['tokens_s']:.2f} "
            f"p50_req_ratio="
            f"{r['tokens_s_p50_request'] / base['tokens_s_p50_request']:.2f}",
        ]
    lines.append(f"spec_flop_ratio_draft_vs_verify,0,"
                 f"ratio={flop_ratio(shared):.4f}")
    return lines


def run() -> list[str]:
    """benchmarks.run section: spec-off baseline, oracle acceptance at
    0.6 and 0.95, and the real branch drafter, all over the paged pool
    (the rollback-accounting path).  bit_identical and leaked_blocks
    ride in the derived column of every BENCH_*.json."""
    base = simulate(spec_k=0)
    shared = base["shared"]
    results = [
        simulate(spec_k=4, alpha=0.6, draft="oracle", shared=shared),
        simulate(spec_k=4, alpha=0.95, draft="oracle", shared=shared),
        simulate(spec_k=4, draft="branch", shared=shared),
    ]
    return report_lines(results, base, shared)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small load (CI smoke): 4 users, 12 tokens")
    ap.add_argument("--users", type=int, default=6)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--alphas", nargs="+", type=float, default=[0.6, 0.95])
    ap.add_argument("--dense", action="store_true",
                    help="dense SlotPool instead of the paged pool")
    ap.add_argument("--model", default="gemma-2b-smoke")
    args = ap.parse_args(argv)
    users, gen = args.users, args.gen
    if args.fast:
        users, gen = min(users, 4), min(gen, 12)

    kw = dict(users=users, gen=gen, slots=args.slots,
              paged=not args.dense)
    base = simulate(args.model, spec_k=0, **kw)
    shared = base["shared"]
    results = [simulate(args.model, spec_k=args.spec_k, alpha=a,
                        draft="oracle", shared=shared, **kw)
               for a in args.alphas]
    results.append(simulate(args.model, spec_k=args.spec_k,
                            draft="branch", shared=shared, **kw))

    print("name,us_per_call,derived")
    for line in report_lines(results, base, shared):
        print(line)

    ok = True
    for r in [base] + results:
        tag = f"{r['draft']} alpha={r['alpha']}"
        if not r["bit_identical"]:
            print(f"FAIL: {tag} diverged from non-speculative greedy "
                  f"decode (speculation must be bit-neutral)")
            ok = False
        if r["leaked_blocks"]:
            print(f"FAIL: {tag} leaked {r['leaked_blocks']} pool blocks "
                  f"after drain (rollback accounting broken)")
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
