"""§Perf hillclimb driver: lower ONE cell with config/sharding overrides
and report the three roofline terms + memory, fast enough to iterate.

  PYTHONPATH=src python -m benchmarks.perf_iter --arch yi_34b \
      --shape train_4k [--trunk dequant|int8_native] [--loss-chunks 8]
      [--attn-chunk 1024] [--moe-group 1024] [--capacity 1.25]
      [--no-remat] [--tag note]

Prints one CSV row:  tag,arch,shape,flops,hbm,coll,tc,tm,tcoll,dom,peakGiB
"""

from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def run_cell(arch: str, shape: str, *, trunk=None, loss_chunks=8,
             attn_chunk=None, moe_group=None, capacity=None, remat=None,
             multi_pod=False, rules=None, tag="iter"):
    import jax
    from repro import configs, optim
    from repro.core import rebranch
    from repro.distributed import sharding as shd
    from repro.launch import steps as steps_lib, hlo_cost
    from repro.launch.mesh import make_production_mesh

    cfg = configs.get(arch)
    over = {}
    if trunk:
        over["rebranch"] = dataclasses.replace(cfg.rebranch,
                                               trunk_impl=trunk)
    if attn_chunk:
        over["attn_chunk"] = attn_chunk
    if moe_group:
        over["moe_group_size"] = moe_group
    if capacity:
        over["moe_capacity_factor"] = capacity
    if remat is not None:
        over["remat"] = remat
    if over:
        cfg = dataclasses.replace(cfg, **over)

    seq, gbatch, kind = dict(
        (s, (q, b, k)) for s, q, b, k in configs.cells(arch))[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)

    with shd.use_mesh(mesh, rules=rules), mesh:
        t_sh, f_sh, opt_sh, param_shapes = steps_lib.model_state_shardings(
            cfg, mesh)
        in_specs = steps_lib.input_specs(cfg, seq, gbatch, kind)
        in_sh = steps_lib.batch_shardings(cfg, mesh, in_specs, gbatch)
        t_shapes, f_shapes = rebranch.partition(param_shapes)
        if kind == "train":
            step = steps_lib.make_train_step(cfg, loss_chunks=loss_chunks)
            opt_shapes = jax.eval_shape(optim.init, t_shapes)
            jitted = jax.jit(step, in_shardings=(t_sh, f_sh, opt_sh, in_sh),
                             donate_argnums=(0, 2))
            lowered = jitted.lower(t_shapes, f_shapes, opt_shapes, in_specs)
        elif kind == "prefill":
            step = steps_lib.make_prefill_step(cfg, gbatch, seq)
            jitted = jax.jit(step, in_shardings=(
                rebranch.combine(t_sh, f_sh), in_sh))
            lowered = jitted.lower(param_shapes, in_specs)
        else:
            step = steps_lib.make_serve_step(cfg)
            c_shapes = steps_lib.cache_specs(cfg, gbatch, seq)
            c_sh = steps_lib.cache_shardings(cfg, mesh, c_shapes)
            jitted = jax.jit(step, in_shardings=(
                rebranch.combine(t_sh, f_sh), in_sh, c_sh),
                donate_argnums=(2,))
            lowered = jitted.lower(param_shapes, in_specs, c_shapes)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        costs = hlo_cost.analyse_text(compiled.as_text())

    tc = costs["flops"] / PEAK_FLOPS
    tm = costs["hbm_bytes"] / HBM_BW
    tcoll = costs["collective_bytes"] / ICI_BW
    dom = max(("compute", tc), ("memory", tm), ("collective", tcoll),
              key=lambda kv: kv[1])[0]
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes) / 2 ** 30
    row = (f"{tag},{arch},{shape},{costs['flops']:.4g},"
           f"{costs['hbm_bytes']:.4g},{costs['collective_bytes']:.4g},"
           f"{tc*1e3:.3f}ms,{tm*1e3:.3f}ms,{tcoll*1e3:.3f}ms,{dom},"
           f"{peak:.1f}GiB")
    print(row, flush=True)
    return {"flops": costs["flops"], "hbm": costs["hbm_bytes"],
            "coll": costs["collective_bytes"], "tc": tc, "tm": tm,
            "tcoll": tcoll, "dom": dom, "peak_gib": peak,
            "collectives": costs["collectives"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--trunk", default=None)
    ap.add_argument("--loss-chunks", type=int, default=8)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--capacity", type=float, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="iter")
    a = ap.parse_args()
    run_cell(a.arch, a.shape, trunk=a.trunk, loss_chunks=a.loss_chunks,
             attn_chunk=a.attn_chunk, moe_group=a.moe_group,
             capacity=a.capacity, remat=False if a.no_remat else None,
             multi_pod=a.multi_pod, tag=a.tag)


if __name__ == "__main__":
    main()
