"""NetStats for the paper's models, computed from the actual JAX models."""

from __future__ import annotations

import functools

import numpy as np
import jax

from repro.core.energy import NetStats
from repro.models import cnn
from repro.configs.paper_models import PAPER_MODELS


def _act_bits(init_fn, apply_fn, cfg, act_bits=8) -> int:
    """Inter-layer activation bits from the jaxpr (conv/dot outputs)."""
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: init_fn(k, cfg), key)
    x = jax.ShapeDtypeStruct((1, cfg.input_size, cfg.input_size, 3),
                             np.float32)
    jaxpr = jax.make_jaxpr(lambda p, xx: apply_fn(p, xx, cfg))(params, x)
    total = 0
    def walk(jpr):
        nonlocal total
        for eqn in jpr.eqns:
            if eqn.primitive.name in ("conv_general_dilated", "dot_general"):
                total += int(np.prod(eqn.outvars[0].aval.shape))
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)
    walk(jaxpr.jaxpr)
    return total * act_bits


@functools.lru_cache(maxsize=None)
def paper_net_stats() -> dict[str, NetStats]:
    out = {}
    schedule = {
        # name: (reload_factor, act_spill, baseline)   — see NetStats doc
        "vgg8": (1.0, False, "all_sram"),
        "resnet18": (1.0, False, "all_sram"),
        "tiny_yolo": (1.0, False, "iso_area"),
        "darknet19": (3.0, True, "iso_area"),
    }
    for name, cfg in PAPER_MODELS.items():
        init_fn, apply_fn = cnn.MODEL_REGISTRY[name]
        n_params, macs = cnn.count_macs_and_params(init_fn, apply_fn, cfg)
        rf, spill, base = schedule[name]
        out[name] = NetStats(
            name=name, params=n_params, macs=macs,
            act_bits_moved=_act_bits(init_fn, apply_fn, cfg),
            reload_factor=rf, act_spill=spill, baseline=base)
    return out
