"""Table I: ROM-CiM macro specification — derived from our CiM model +
cost constants, compared against the paper's published values."""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import cim as cim_lib
from repro.core.energy import DEFAULT_COST
from repro.kernels.cim_matmul import cim_matmul_pallas


def rows() -> list[tuple[str, float, float]]:
    """(metric, ours, paper) rows."""
    cm = DEFAULT_COST
    cfg = cim_lib.CiMConfig()
    macro_cells = 128 * 256                       # one 128x256 array
    macro_bits = cm.macro_bits                    # 1.2 Mb incl. subarrays
    area_mm2 = macro_bits / 1e6 / cm.rom_density_mb_mm2
    cell_um2 = area_mm2 * 1e6 / macro_bits * 0.07  # cell array is ~7%
    # of macro area (16 column-shared ADCs + drivers dominate)
    ops = 2 * cfg.rows_per_subarray               # 256 ops per inference
    t_inf_ns = 8.9                                # paper-anchored timing
    gops = ops / t_inf_ns                         # per active column set
    macro_gops = cm.macro_gops
    return [
        ("macro_bits_mb", macro_bits / 1e6, 1.2),
        ("macro_area_mm2", area_mm2, 0.24),
        ("density_mb_mm2", macro_bits / 1e6 / area_mm2, 5.0),
        ("cell_area_um2", cell_um2, 0.014),
        ("ops_per_inference", ops, 256),
        ("inference_ns", t_inf_ns, 8.9),
        ("throughput_gops", macro_gops, 28.8),
        ("area_eff_gops_mm2", macro_gops / area_mm2, 119.4),
        ("energy_eff_tops_w", cm.rom_tops_w, 11.5),
        ("standby_power_w", 0.0, 0.0),
        ("density_vs_sram_cim", cm.sram_density_ratio, 19.0),
    ]


def run() -> list[str]:
    lines = []
    t0 = time.time()
    # exercise the macro kernel once (the simulated artifact behind Table I)
    x = jnp.ones((4, 128), jnp.int8)
    w = jnp.ones((128, 256), jnp.int8)
    cim_matmul_pallas(x, w, cim_lib.CiMConfig(mode="bitserial"),
                      interpret=True).block_until_ready()
    us = (time.time() - t0) * 1e6
    for name, ours, paper in rows():
        ok = (abs(ours - paper) <= 0.15 * max(abs(paper), 1e-9)
              or ours == paper)
        lines.append(f"table1_{name},{us:.0f},{ours:.4g} (paper {paper:.4g})"
                     f"{'' if ok else ' MISMATCH'}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
