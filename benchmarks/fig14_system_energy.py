"""Fig. 14: system-level energy-efficiency comparison.

Paper claims (vs the SRAM-CiM baseline of Fig. 13): 4.8x (ResNet-18),
10.2x (Tiny-YOLO), 14.8x (YOLO / DarkNet-19); ~2% better than the chiplet
configuration with ~10x less total chip area; ReBranch latency overhead
~8% on YOLO."""

from __future__ import annotations

import time

from benchmarks import netstats
from repro.core import energy


PAPER = {"resnet18": 4.8, "tiny_yolo": 10.2, "darknet19": 14.8}


def run() -> list[str]:
    lines = []
    t0 = time.time()
    stats = netstats.paper_net_stats()
    us = (time.time() - t0) * 1e6
    for name, paper_x in PAPER.items():
        ns = stats[name]
        ours = energy.efficiency_ratio(ns)
        e_y = energy.yoloc_energy(ns)
        e_s = energy.sram_single_energy(ns)
        lines.append(f"fig14_energy_ratio_{name},{us:.0f},{ours:.2f}x "
                     f"(paper {paper_x}x)")
        lines.append(
            f"fig14_breakdown_{name},{us:.0f},"
            f"yoloc[mac={e_y['mac']*1e3:.2f} cache={e_y['cache']*1e3:.2f}]uJ"
            f" sram[mac={e_s['mac']*1e3:.2f} dram={e_s['dram']*1e3:.2f}"
            f" cache={e_s['cache']*1e3:.2f}]uJ")
    # chiplet comparison (YOLO): YOLoC should be slightly better on energy
    # with ~10x area saving
    ns = stats["darknet19"]
    e_y = energy.yoloc_energy(ns)["total"]
    e_c = energy.chiplet_energy(ns)["total"]
    lines.append(f"fig14_vs_chiplet_energy,{us:.0f},{e_c/e_y:.3f}x "
                 f"(paper ~1.02x)")
    n_chips = energy.chiplet_energy(ns)["n_chips"]
    chiplet_area = n_chips * (energy.DEFAULT_COST.chiplet_bits / 1e6
                              / energy.DEFAULT_COST.sram_density_mb_mm2)
    lines.append(f"fig14_vs_chiplet_area,{us:.0f},"
                 f"{chiplet_area/energy.yoloc_area(ns):.1f}x "
                 f"(paper ~10x)")
    lat = energy.yoloc_latency(ns)
    lines.append(f"fig14_latency_overhead_yolo,{us:.0f},"
                 f"{lat['overhead_frac']:.3f} (paper 0.08)")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
