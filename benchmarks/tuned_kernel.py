"""Tuning-table payoff: untuned dispatch vs table-tuned dispatch.

Times ``kernels.rebranch_conv`` on DarkNet-19 patch-GEMM geometries
under three tiling resolutions:

  grid    : the ``pallas_call`` macro grid, forced via ``interpret=True``
            (off-TPU this is the interpreter — the dispatch the seed
            benchmarks ran before the tuning table existed)
  default : direct lowering with the per-kernel default tiling, table
            lookups disabled (``repro.tune.table.disabled()``)
  tuned   : whatever ``repro/tune/tuning_table.json`` resolves for the
            geometry (the shipping dispatch)

``default`` and ``tuned`` are bit-identical by construction — the table
may only hand out tilings that preserve the kernel's k-partition — and
this section asserts exact equality before timing, so a table edit that
changed the bits would fail the benchmark run, not just the gate.  The
grid path is tolerance-equal (its f32 slab accumulation rounds through
different intermediates).

  PYTHONPATH=src python -m benchmarks.tuned_kernel
"""

from __future__ import annotations

import importlib

import jax
import numpy as np

from benchmarks.conv_kernel import _time, darknet_layer_shapes
from repro.core.rebranch import ReBranchSpec
from repro.models import cnn
from repro.tune import table as tune_table

# the package re-exports a jitted op named ``rebranch_conv`` that shadows
# the submodule, so ``import ... as`` would bind the op — go via importlib
_rc = importlib.import_module("repro.kernels.rebranch_conv")

# one geometry per patch-matrix regime the tuner distinguishes:
# l2 = mid 3x3 (gk=2, ragged 64-wide tail), l5 = deep 3x3 (gk=3)
_LAYERS = (2, 5)


def bench_geometry(i: int, c_in: int, c_out: int, k: int, hw: int,
                   repeat: int, key) -> dict[str, float]:
    p = cnn.init_conv(key, k, c_in, c_out, ReBranchSpec())
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, hw, hw, c_in))
    rom, sram = p["rom"], p["sram"]
    args = (rom["w_q"], rom["w_scale"], rom["C"], sram["core"], rom["U"])

    grid = jax.jit(lambda x: _rc.rebranch_conv_pallas(
        x, *args, interpret=True))
    default = jax.jit(lambda x: _rc.rebranch_conv_pallas(x, *args))
    tuned = jax.jit(lambda x: _rc.rebranch_conv_pallas(x, *args))

    # tilings resolve at trace time: warm ``default`` inside the
    # disabled() scope so its trace bakes in the per-kernel defaults
    with tune_table.disabled():
        ref = np.asarray(default(x))
    assert np.array_equal(ref, np.asarray(tuned(x))), (
        f"tuned tiling changed the bits at layer {i} "
        f"(cin={c_in} cout={c_out} k={k} hw={hw})")
    # the interpret grid accumulates through f32 slab copies — same
    # algorithm, not the same ulps, so tolerance-equal only
    np.testing.assert_allclose(ref, np.asarray(grid(x)),
                               rtol=2e-5, atol=2e-5)

    out = {"grid": _time(grid, x, repeat=repeat)}
    with tune_table.disabled():
        out["default"] = _time(default, x, repeat=repeat)
    out["tuned"] = _time(tuned, x, repeat=repeat)
    return out


def run() -> list[str]:
    """benchmarks.run section (gated: see benchmarks.compare).

    Off-TPU the ``grid`` rows time the Pallas interpreter — they are the
    honest "what the seed shipped" baseline, not a TPU grid projection;
    ``default`` vs ``tuned`` isolates what the checked-in table buys on
    the direct lowering.
    """
    key = jax.random.PRNGKey(0)
    shapes = darknet_layer_shapes(32, 6)
    lines = []
    for i in _LAYERS:
        c_in, c_out, k, hw = shapes[i]
        times = bench_geometry(i, c_in, c_out, k, hw, repeat=3,
                               key=jax.random.fold_in(key, i))
        for name, ms in times.items():
            lines.append(f"tuned_kernel_l{i}_{name},{ms * 1e3:.0f},"
                         f"cin={c_in} cout={c_out} k={k} hw={hw}")
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line, flush=True)
