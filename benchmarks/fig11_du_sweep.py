"""Fig. 11: ReBranch hyperparameter sweep — compression ratio D*U vs
transfer accuracy and area saving.  Paper: D=U=4 (16x) is the sweet spot."""

from __future__ import annotations

import time

from benchmarks import transfer_harness as th


def run() -> list[str]:
    lines = []
    accs = {}
    for d, u in [(2, 2), (4, 4), (8, 8)]:
        t0 = time.time()
        acc, frac = th.run_transfer("rebranch", d_ratio=d, u_ratio=u)
        us = (time.time() - t0) * 1e6
        accs[d * u] = acc
        lines.append(f"fig11_DU{d}x{u}_acc,{us:.0f},{acc:.4f} "
                     f"(compression {d*u}x, trainable {frac:.4f})")
    # the paper's point: 16x compresses well without falling off the cliff
    drop_16 = accs[4] - accs[16]
    drop_64 = accs[4] - accs[64]
    lines.append(f"fig11_acc_drop_4to16x,0,{drop_16:.4f}")
    lines.append(f"fig11_acc_drop_4to64x,0,{drop_64:.4f} "
                 f"(should exceed the 16x drop)")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
