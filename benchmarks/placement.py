"""Placement-plan stats for the paper CNNs (the Fig. 12 map as metrics).

One analytic pass per model: the cost-driven solver's all-ROM design
point (every trunk in ROM-CiM + SRAM ReBranch — YOLoC's deployment) and
a mid-budget solve, reported as ROM / SRAM-branch bits, MACs, area and
the iso-area-SRAM energy ratio.  Wall time is the solver's own cost
(site enumeration + greedy assignment — pure python), so these rows are
cheap enough for every CI run; values are model outputs, not
performance, and are never gated.
"""

from __future__ import annotations

import time

from repro import plan
from repro.configs.paper_models import PAPER_MODELS
from repro.launch.dryrun import FIG12_MODELS


def run() -> list[str]:
    lines = []
    for name, reload_factor in FIG12_MODELS.items():
        cfg = PAPER_MODELS[name]
        t0 = time.time()
        design = plan.solve(cfg)                    # all-ROM design point
        stats = design.stats(cfg)
        area = plan.plan_area_mm2(stats)
        eff = plan.efficiency_vs_iso_sram(stats, reload_factor=reload_factor)
        # mid-budget point: half-way to the all-SRAM area
        mid = plan.sweep(cfg, 3, reload_factor=reload_factor)[1]
        us = (time.time() - t0) * 1e6
        lines.append(f"placement_rom_mbit_{name},{us:.0f},"
                     f"{stats.rom_bits / 1e6:.2f}Mbit rom")
        lines.append(f"placement_branch_mbit_{name},{us:.0f},"
                     f"{stats.branch_bits / 1e6:.2f}Mbit sram branch")
        lines.append(f"placement_design_area_{name},{us:.0f},"
                     f"{area:.1f}mm2 eff {eff:.1f}x vs iso-area sram")
        lines.append(f"placement_mid_budget_{name},{us:.0f},"
                     f"{mid['sram_sites']}/{stats.sites} sites sram at "
                     f"{mid['budget_mm2']:.0f}mm2 eff {mid['efficiency_x']}x")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
