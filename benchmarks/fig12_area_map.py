"""Fig. 12: chip area and detection quality for YOLO / Tiny-YOLO.

Paper claims: YOLoC beats all-SRAM-CiM area by 9.7x (YOLO) and 2.4x
(Tiny-YOLO) with ~no mAP change (-0.5%..+0.2%).  Area ratios come from the
cost model on the real DarkNet-19/Tiny-YOLO parameter counts; the
detection-quality proxy reuses the Fig.-10 transfer gap (mAP needs a real
VOC set, unavailable offline — documented in EXPERIMENTS.md)."""

from __future__ import annotations

import time

from benchmarks import netstats
from repro.core import energy


def run() -> list[str]:
    lines = []
    t0 = time.time()
    stats = netstats.paper_net_stats()
    us = (time.time() - t0) * 1e6
    yolo = stats["darknet19"]
    ours = energy.area_ratio(yolo)
    lines.append(f"fig12_area_ratio_darknet19,{us:.0f},{ours:.2f}x "
                 f"(paper 9.7x)")
    lines.append(f"fig12_yoloc_area_darknet19,{us:.0f},"
                 f"{energy.yoloc_area(yolo):.1f}mm2")
    lines.append(f"fig12_allsram_area_darknet19,{us:.0f},"
                 f"{energy.all_sram_area(yolo):.1f}mm2")
    # Fig. 12 footnote: Tiny-YOLO is "a smaller backbone in the same
    # framework (all layers trainable)" — the 2.4x compares the all-SRAM
    # Tiny-YOLO chip against the (YOLO-capable) YOLoC chip.
    ty = stats["tiny_yolo"]
    ratio_ty = energy.all_sram_area(ty) / energy.yoloc_area(yolo)
    lines.append(f"fig12_area_ratio_tiny_yolo,{us:.0f},{ratio_ty:.2f}x "
                 f"(paper 2.4x)")
    lines.append(f"fig12_allsram_area_tiny_yolo,{us:.0f},"
                 f"{energy.all_sram_area(ty):.1f}mm2")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
