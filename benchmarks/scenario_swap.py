"""Scenario hot-swap benchmark: K branches over ONE resident ROM trunk.

The tentpole claim of the scenario subsystem (`repro.scenario`): once a
trunk is resident, switching the chip to another dataset/task is a
branch swap — one donated combine over the fixed ROM image — not a
model reload.  This benchmark makes that a measured number:

  1. pretrain a VGG-8 on synthetic task A and tape it out to ROM
     (``transfer_harness``, the Fig. 10 flow);
  2. train K distinct ReBranch-only scenarios on the SAME trunk
     (one synthetic transfer target each);
  3. register them with the serving layer and race
        branch hot-swap  (``CNNServer.swap_scenario``: donated combine,
                          resident jit executable reused)
     against
        full reload      (``registry.evict`` + ``compile_entry`` +
                          fresh jit forward — what serving a new
                          scenario costs WITHOUT the subsystem);
  4. verify the correctness bar: a hot-swapped branch is bit-identical
     to a freshly compiled single-scenario cell, and each scenario's
     eval accuracy through the serve path matches the direct path.

Emits ``name,us_per_call,derived`` CSV rows (``--json`` for records);
wired into ``benchmarks.run`` and gated by ``benchmarks.compare``.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import transfer_harness as th
from repro import deploy, scenario, serve
from repro import plan as plan_lib
from repro.core import rebranch
from repro.core.rebranch import ReBranchSpec
from repro.data import synthetic
from repro.models import cnn

MODEL_ID = "vgg8-swap-bench"


def _fresh(params):
    """A deep copy — keeps a reference tree alive across donated swaps."""
    return jax.tree.map(lambda x: jnp.array(x), params)


def _accuracy_from(predict, tc, seed):
    correct = total = 0
    for i in range(tc.eval_batches):
        x, y = synthetic.image_batch(seed, 10_000 + i, tc.batch,
                                     tc.input_size, tc.num_classes)
        pred = np.argmax(predict(x), axis=-1)
        correct += int(np.sum(pred == np.asarray(y)))
        total += tc.batch
    return correct / total


def simulate(k: int = 2, tc: th.TransferConfig | None = None,
             swap_reps: int = 10) -> dict:
    """Train K scenario branches on one trunk, then measure swap vs
    reload latency and per-scenario serve/direct accuracy parity."""
    tc = tc or th.TransferConfig()
    dense, _ = th.pretrained_dense(tc)
    spec = ReBranchSpec()
    cfg = th.small_vgg_cfg(spec, tc)
    plan = plan_lib.PlacementPlan.from_config(cfg)
    frozen = cnn.freeze_to_rom(dense, jax.random.PRNGKey(7), spec)

    # -- K scenarios: branch-only transfer to K distinct tasks ----------
    model = deploy.compile_model(cfg, plan=plan)
    names, bundles, seeds, acc_direct = [], {}, {}, {}
    for i in range(k):
        name = f"task{i}"
        seed = tc.seed_b + 1000 * i
        p_i = th._train(_fresh(frozen), model.cfg, tc, seed,
                        tc.finetune_steps)
        bundles[name] = scenario.extract(model, p_i, plan)
        acc_direct[name] = _accuracy_from(
            lambda x: np.asarray(model.forward(p_i, x)), tc, seed)
        names.append(name)
        seeds[name] = seed

    # -- serve them all from one resident cell --------------------------
    serve.register(serve.ModelEntry(
        MODEL_ID, config=lambda: cfg, plan=lambda c: plan), override=True)
    store = serve.scenario_store(MODEL_ID, capacity=max(2, k))
    for name in names:
        store.register(name, bundle=bundles[name], override=True)
    srv = serve.load(MODEL_ID, params=_fresh(frozen), n_slots=tc.batch,
                     scenario=names[0])
    xw, _ = synthetic.image_batch(tc.seed_b, 10_000, tc.batch,
                                  tc.input_size, tc.num_classes)
    srv.submit(xw)                                   # warm the jit cell

    # -- swap latency: donated combine + resident executable ------------
    swap_times = []
    for r in range(swap_reps):
        target = names[(r + 1) % len(names)]
        t0 = time.perf_counter()
        srv.swap_scenario(target)
        jax.block_until_ready(srv.params)
        swap_times.append(time.perf_counter() - t0)
    swap_us = float(np.median(swap_times) * 1e6)

    # -- full reload: what the swap replaces ----------------------------
    reload_times = []
    for _ in range(2):
        serve.evict(MODEL_ID)
        t0 = time.perf_counter()
        srv2 = serve.load(MODEL_ID, params=_fresh(frozen), n_slots=tc.batch)
        np.asarray(srv2.submit(xw))                  # fresh jit compile
        reload_times.append(time.perf_counter() - t0)
    reload_us = float(min(reload_times) * 1e6)
    store = serve.scenario_store(MODEL_ID, capacity=max(2, k))
    for name in names:
        store.register(name, bundle=bundles[name], override=True)
    srv = serve.load(MODEL_ID, params=_fresh(frozen), n_slots=tc.batch)
    srv.submit(xw)

    # -- correctness bar: bitwise vs a freshly compiled cell ------------
    trunk = rebranch.partition(frozen)[1]
    acc_serve, parity = {}, {}
    for name in names:
        srv.swap_scenario(name)
        got = np.asarray(srv.submit(xw))
        fresh_model = deploy.compile_model(cfg, plan=plan)
        p_fresh = rebranch.combine(bundles[name].params, trunk)
        want = np.asarray(jax.jit(fresh_model.forward)(p_fresh,
                                                       jnp.asarray(xw)))
        parity[name] = bool(np.array_equal(got, want))
        acc_serve[name] = _accuracy_from(
            lambda x: np.asarray(srv.submit(x)), tc, seeds[name])
    return {
        "k": k, "swap_us": swap_us, "reload_us": reload_us,
        "speedup": reload_us / swap_us,
        "bit_identical": all(parity.values()),
        "parity": parity, "acc_serve": acc_serve,
        "acc_direct": acc_direct,
        "cache": {"hits": store.hits, "misses": store.misses,
                  "evicted": list(store.evicted)},
    }


def report_lines(r: dict) -> list[str]:
    """CSV rows for benchmarks.run; wall_us rows feed the CI gate."""
    lines = [
        f"scenario_swap_us,{r['swap_us']:.0f},"
        f"k={r['k']} speedup={r['speedup']:.1f}x_vs_reload "
        f"bit_identical={r['bit_identical']}",
        f"scenario_full_reload_us,{r['reload_us']:.0f},"
        f"compile_entry+jit_warm (the cost a hot-swap replaces)",
        f"scenario_swap_speedup,0,{r['speedup']:.1f}x "
        f"(acceptance: >=5x)",
    ]
    for name in sorted(r["acc_serve"]):
        lines.append(
            f"scenario_acc_{name},0,serve={r['acc_serve'][name]:.4f} "
            f"direct={r['acc_direct'][name]:.4f} "
            f"parity={r['parity'][name]}")
    return lines


def run() -> list[str]:
    """benchmarks.run section: 3 scenarios on one trunk, reduced
    training budget (the accuracy rows are parity checks, not Fig. 10
    reproductions — fig10_generalization owns the headline accuracy)."""
    tc = th.TransferConfig(pretrain_steps=80, finetune_steps=80,
                           eval_batches=4)
    return report_lines(simulate(k=3, tc=tc))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: 2 scenarios, short training")
    ap.add_argument("--k", type=int, default=3,
                    help="number of scenario branches to train")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the result record as JSON")
    args = ap.parse_args(argv)
    if args.fast:
        tc = th.TransferConfig(pretrain_steps=40, finetune_steps=40,
                               eval_batches=2)
        args.k = min(args.k, 2)
    else:
        tc = th.TransferConfig(pretrain_steps=80, finetune_steps=80,
                               eval_batches=4)
    r = simulate(k=args.k, tc=tc)
    print("name,us_per_call,derived")
    for line in report_lines(r):
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(r, f, indent=1)
    if not r["bit_identical"]:
        print("FAIL: hot-swapped branch diverged from a freshly "
              "compiled single-scenario cell")
        return 1
    if r["speedup"] < 5.0:
        print(f"FAIL: swap only {r['speedup']:.1f}x faster than a full "
              f"reload (acceptance: >=5x)")
        return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
