"""Conv trunk kernel wall-clock: XLA fake-quant baseline vs Pallas fused.

Times DarkNet-19-shaped ReBranch conv layers (the paper's headline
detection backbone) under the three trunk dispatches:

  dequant  : dequantised weights + fake-quantised activations, XLA conv
             (the paper-faithful baseline)
  pallas   : kernels.trunk_conv — fused im2col kernel (quantise in VMEM,
             int8 MXU dots, scale epilogue) + XLA branch
  fused    : kernels.rebranch_conv — trunk AND compress sketch in one
             pass over the patch matrix (inference fast path)

  PYTHONPATH=src python -m benchmarks.conv_kernel [--size 104] [--batch 1]
      [--layers 6] [--repeat 5] [--tag note]

Prints CSV rows:  tag,layer,cin,cout,k,hw,impl,ms

NOTE: off-TPU the Pallas kernels run in interpret mode — wall-clock there
measures the interpreter, not the kernel; use the XLA rows as the CPU
baseline and run on TPU for the real comparison.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.rebranch import ReBranchSpec
from repro.kernels import ops
from repro.models import cnn


def darknet_layer_shapes(size: int, max_layers: int):
    """(c_in, c_out, k, hw) per conv of DarkNet-19 at input `size`."""
    shapes, c_in, hw = [], 3, size
    for item in cnn.DARKNET19:
        if item == "M":
            hw //= 2
            continue
        c, k = item
        shapes.append((c_in, c, k, hw))
        c_in = c
    return shapes[:max_layers]


def _time(fn, *args, repeat: int) -> float:
    """Best-of-``repeat`` wall ms (min, not mean: scheduler noise and GC
    pauses only ever ADD time, so the minimum is the least-noisy
    estimate of kernel cost — what the CI regression gate should see)."""
    jax.block_until_ready(fn(*args))              # compile + warm cache
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def bench_layer(c_in: int, c_out: int, k: int, hw: int, batch: int,
                repeat: int, key) -> dict[str, float]:
    spec = ReBranchSpec()
    p = cnn.init_conv(key, k, c_in, c_out, spec)
    x = jax.random.normal(jax.random.fold_in(key, 1), (batch, hw, hw, c_in))
    rom, sram = p["rom"], p["sram"]

    dequant = jax.jit(lambda x: cnn.apply_conv(
        p, x, ReBranchSpec(trunk_impl="dequant")))
    pallas = jax.jit(lambda x: cnn.apply_conv(
        p, x, ReBranchSpec(trunk_impl="pallas")))
    fused = jax.jit(lambda x: ops.rebranch_conv(
        x, rom["w_q"], rom["w_scale"], rom["C"], sram["core"], rom["U"]))

    # two interleaved rounds per impl, keep the min: machine-load drift
    # between the dequant and fused measurements is the dominant noise
    # term on a shared core, and interleaving cancels it
    impls = [("dequant", dequant), ("pallas", pallas), ("fused", fused)]
    out = {name: float("inf") for name, _ in impls}
    for _ in range(2):
        for name, fn in impls:
            out[name] = min(out[name], _time(fn, x, repeat=repeat))
    # sanity: the paths agree (loose: different act-quant granularity)
    np.testing.assert_allclose(np.asarray(dequant(x)), np.asarray(fused(x)),
                               rtol=0.1, atol=0.1)
    return out


def run() -> list[str]:
    """benchmarks.run section: one DarkNet-19 layer per conv class at
    32px — the stem 3x3 (l0), a mid-depth 3x3 (l2), and a deep
    small-spatial 3x3 (l5) — spanning the patch-matrix geometries
    (gk=1 narrow, gk=2 ragged-tail, gk=3) the fused kernel dispatches
    over.  Off-TPU this is interpret mode — relative numbers only; use
    main() on TPU for the real comparison.  repeat=5 best-of with
    interleaved rounds: these rows feed the CI regression gate
    (benchmarks.compare), so single-shot timer noise would gate on
    load spikes instead of kernels."""
    key = jax.random.PRNGKey(0)
    shapes = darknet_layer_shapes(32, 6)
    lines = []
    for i in (0, 2, 5):
        c_in, c_out, k, hw = shapes[i]
        times = bench_layer(c_in, c_out, k, hw, batch=1, repeat=5,
                            key=jax.random.fold_in(key, i))
        for impl, ms in times.items():
            lines.append(f"conv_kernel_l{i}_{impl},{ms * 1e3:.0f},"
                         f"cin={c_in} cout={c_out} k={k} hw={hw}")
    lines.append(sketch_flops_line())
    return lines


def sketch_flops_line(c_in: int = 1024, k: int = 3, d_ratio: int = 4) -> str:
    """The structured-compress win as data: branch-sketch FLOPs per patch
    row for a wide DarkNet-19 layer, per-tap structured dot (what
    kernels.rebranch_conv now runs) vs the old dense ``kron(I_taps, C)``
    densification.  The ratio is exactly ``taps`` (k*k), independent of
    channel width — analytic, wall_us=0, never regression-gated."""
    taps, c_c = k * k, c_in // d_ratio
    structured = 2 * taps * c_in * c_c
    dense = 2 * taps * taps * c_in * c_c
    return (f"conv_kernel_sketch_flops_per_row,0,"
            f"structured={structured / 1e6:.1f}MF dense_kron="
            f"{dense / 1e6:.1f}MF win={dense / structured:.0f}x "
            f"(cin={c_in} k={k} D={d_ratio})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=104,
                    help="input resolution (DarkNet-19 native: 416)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--layers", type=int, default=6,
                    help="how many DarkNet-19 convs to time")
    ap.add_argument("--repeat", type=int, default=5)
    ap.add_argument("--tag", default="conv")
    a = ap.parse_args()

    print(f"# backend={jax.default_backend()} "
          f"(interpret mode off-TPU — see module docstring)")
    print("tag,layer,cin,cout,k,hw,impl,ms")
    key = jax.random.PRNGKey(0)
    for i, (c_in, c_out, k, hw) in enumerate(
            darknet_layer_shapes(a.size, a.layers)):
        times = bench_layer(c_in, c_out, k, hw, a.batch, a.repeat,
                            jax.random.fold_in(key, i))
        for impl, ms in times.items():
            print(f"{a.tag},{i},{c_in},{c_out},{k},{hw},{impl},{ms:.2f}",
                  flush=True)
    print(f"# {sketch_flops_line()}")


if __name__ == "__main__":
    main()
