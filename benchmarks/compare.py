"""CI benchmark regression gate: current run vs the checked-in baseline.

Compares a ``benchmarks.run --json`` output against the newest
``BENCH_*.json`` at the repo root and fails (exit 1) when any
kernel-parity metric — the ``conv_kernel`` section, where the fused
Pallas kernels race the XLA baseline on identical layers, and the
``tuned_kernel`` section, where the tuning-table dispatch races the
untuned defaults — regresses by more than ``--max-ratio`` (default 2x)
in wall time.  ``--ratchet R`` additionally prints informational
RATCHET lines for gated metrics now more than R times FASTER than the
baseline: a stale baseline's slack hides future regressions, and the
fix is to check in a fresh ``BENCH_<n+1>.json``.

Only metrics present in BOTH files are compared (a --fast run gates
against the overlapping subset of a full-run baseline), and metrics
below ``--min-us`` in the baseline are skipped: timer noise at the
microsecond floor is not a regression.  Analytic sections (area map,
energy, roofline) carry wall_us=0 and are never gated — their values
are model outputs, not performance.

Usage:
    python -m benchmarks.compare bench.json [--baseline BENCH_4.json]
        [--max-ratio 2.0] [--min-us 100]
"""

import argparse
import glob
import json
import os
import re
import sys

# sections whose wall_us measures kernel execution (gate-worthy); the
# rest are analytic tables where wall time is incidental
GATED_SECTIONS = ("conv_kernel", "tuned_kernel", "serve_load",
                  "scenario_swap", "spec_decode")

# metric-name markers for rows whose VALUE is a dimensionless statistic
# (acceptance rates, speedup ratios), not a wall time: gating them as
# latencies would flag "acceptance went from 0.6 to 0.3" as a 2x
# TIME regression (or, worse, bless a real slowdown that halved a
# ratio).  They ride in BENCH_*.json for the record but never gate.
_RATIO_MARKERS = ("acceptance", "ratio", "rate")


def is_ratio_metric(name: str) -> bool:
    """Whether a metric row carries a ratio/rate, not a wall time."""
    return any(m in name for m in _RATIO_MARKERS)


def latest_baseline(root: str) -> str | None:
    """The highest-numbered BENCH_<n>.json at the repo root."""
    paths = glob.glob(os.path.join(root, "BENCH_*.json"))

    def key(p):
        m = re.search(r"BENCH_(\d+)\.json$", p)
        return int(m.group(1)) if m else -1

    return max(paths, key=key) if paths else None


def load_metrics(path: str) -> dict[str, dict]:
    with open(path) as f:
        return {(r["section"], r["metric"]): r for r in json.load(f)}


def compare(current: dict, baseline: dict, *, max_ratio: float,
            min_us: float) -> list[str]:
    """Regression messages for every gated metric exceeding the ratio."""
    problems = []
    for key, base in baseline.items():
        if key[0] not in GATED_SECTIONS or base["wall_us"] < min_us \
                or is_ratio_metric(key[1]):
            continue
        cur = current.get(key)
        if cur is None:
            continue                     # --fast subset vs full baseline
        ratio = cur["wall_us"] / base["wall_us"]
        if ratio > max_ratio:
            problems.append(
                f"{key[0]}/{key[1]}: {cur['wall_us']:.0f}us vs baseline "
                f"{base['wall_us']:.0f}us ({ratio:.2f}x > {max_ratio}x)")
    return problems


def ratchet(current: dict, baseline: dict, *, min_ratio: float,
            min_us: float) -> list[str]:
    """Gated metrics now >``min_ratio`` FASTER than the baseline.

    The inverse of :func:`compare`: after a kernel optimisation lands,
    the old baseline's slack hides future regressions (a 2x gate against
    a number that is now 2x stale tolerates a 4x slowdown).  These are
    informational — the fix is to check in a fresh ``BENCH_<n+1>.json``,
    which re-tightens the gate, so the exit code stays 0.
    """
    wins = []
    for key, base in baseline.items():
        if key[0] not in GATED_SECTIONS or base["wall_us"] < min_us \
                or is_ratio_metric(key[1]):
            continue
        cur = current.get(key)
        if cur is None or cur["wall_us"] <= 0:
            continue
        ratio = base["wall_us"] / cur["wall_us"]
        if ratio > min_ratio:
            wins.append(
                f"{key[0]}/{key[1]}: {cur['wall_us']:.0f}us vs baseline "
                f"{base['wall_us']:.0f}us ({ratio:.2f}x faster — baseline "
                f"is stale)")
    return wins


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="benchmarks.run --json output to check")
    ap.add_argument("--baseline", default=None,
                    help="baseline json (default: newest BENCH_*.json)")
    ap.add_argument("--max-ratio", type=float, default=2.0)
    ap.add_argument("--min-us", type=float, default=100.0,
                    help="skip baseline metrics below this (timer noise)")
    ap.add_argument("--ratchet", type=float, default=None, metavar="RATIO",
                    help="also flag gated metrics more than RATIO times "
                         "FASTER than the baseline (stale baseline — check "
                         "in a fresh BENCH_*.json); informational, exit 0")
    ap.add_argument("--require-baseline", action="store_true",
                    help="fail (exit 1) when no baseline exists instead of "
                         "passing vacuously — a missing/mis-globbed "
                         "BENCH_*.json silently disables the CI gate "
                         "otherwise")
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    baseline_path = args.baseline or latest_baseline(root)
    if baseline_path is None:
        print("no BENCH_*.json baseline found — nothing to gate against")
        return 1 if args.require_baseline else 0

    current = load_metrics(args.current)
    baseline = load_metrics(baseline_path)
    problems = compare(current, baseline, max_ratio=args.max_ratio,
                       min_us=args.min_us)
    n_gated = sum(1 for k, r in baseline.items()
                  if k[0] in GATED_SECTIONS and r["wall_us"] >= args.min_us
                  and not is_ratio_metric(k[1]) and k in current)
    print(f"compared {n_gated} kernel metrics against "
          f"{os.path.basename(baseline_path)}")
    for p in problems:
        print(f"REGRESSION: {p}")
    if args.ratchet is not None:
        for w in ratchet(current, baseline, min_ratio=args.ratchet,
                         min_us=args.min_us):
            print(f"RATCHET: {w}")
    if problems:
        return 1
    print("benchmark gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
