import os
import sys

# make `benchmarks` importable from tests without installing the package
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
