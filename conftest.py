import os
import sys

# make `benchmarks` importable from tests without installing the package,
# and `_prop` (the hypothesis shim) importable from anywhere
_ROOT = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "tests"))
