"""The 'pallas_sharded' TrunkEngine: halo-exchange conv over a mesh.

The registry's first out-of-`builtin` backend — proof the engine seam is
real.  Conv is the native sharded op: NHWC activations shard over H on
the mesh axis the ``"cnn_h"`` logical rule names (``"data"`` by
default), each device exchanges only the kernel halo with its
neighbours (``jax.lax.ppermute``) and runs the fused im2col Pallas
kernel on its slab — bit-identical to the unsharded 'pallas' engine (see
``kernels/halo_conv.py`` for the halo math and the parity argument).

Honest capabilities: ``sharded_ops=("conv",)`` — matmul simply delegates
to the stock 'pallas' engine (LM trunks already shard tensor-parallel
through GSPMD; spatial halo exchange buys nothing there).  Conv also
degrades gracefully: no mesh in scope, a 1-sized axis, or an H too small
for the mesh (halo would span >1 neighbour shard) all fall back to the
unsharded 'pallas' conv — correct, just not sharded.
"""

from __future__ import annotations

import warnings

from repro.distributed import sharding as shd
from repro.engine import base
from repro.engine.registry import get, register

# (H, kh, stride, padding, n_shards) combos already warned about — the
# halo-doesn't-fit fallback is correct but silently losing the sharding a
# deployment asked for is surprising, so it warns once per geometry.
_warned_fallbacks: set = set()


class ShardedPallasEngine(base.TrunkEngine):
    """Halo-exchange H-sharded Pallas conv; matmul delegates to 'pallas'."""

    name = "pallas_sharded"
    capabilities = base.EngineCapabilities(
        fidelity_modes=("ideal", "per_subarray", "bitserial"),
        grads=True, devices=("tpu",), epilogue=True,
        sharded_ops=("conv",), tune=True)

    # the logical axis whose sharding rule names the mesh axis H shards over
    h_axis = "cnn_h"

    def matmul(self, cfg, x, w_q, w_scale, *, out_axes=None):
        return get("pallas").matmul(cfg, x, w_q, w_scale, out_axes=out_axes)

    def _mesh_axis(self, x, kh: int, stride: int, padding: str):
        """(mesh, axis) when the sharded path applies, else (None, None).

        mesh_axis_for already skips size-1 axes; the feasibility probe
        (trace-time integer math, the kernel re-derives the same plan)
        routes too-small-H cases to the unsharded fallback instead of
        letting sharded_trunk_conv's direct-caller guard raise."""
        from repro.kernels import halo_conv     # deferred: optional dep
        mesh = shd.current_mesh()
        if mesh is None:
            return None, None
        axis = shd.mesh_axis_for(self.h_axis, mesh)
        if axis is None:
            return None, None
        n = mesh.shape[axis]
        plan = halo_conv.plan_halo(x.shape[1], kh, stride, padding, n)
        if plan is None:                        # H too small for this mesh
            key = (x.shape[1], kh, stride, padding, n)
            if key not in _warned_fallbacks:
                _warned_fallbacks.add(key)
                warnings.warn(
                    f"pallas_sharded: halo for H={x.shape[1]} kh={kh} "
                    f"stride={stride} {padding} does not fit a "
                    f"{n}-way '{axis}' mesh axis (it would span more "
                    f"than one neighbour shard); falling back to the "
                    f"unsharded 'pallas' conv for this layer",
                    stacklevel=3)
            return None, None
        return mesh, axis

    def conv(self, cfg, x, w_q, w_scale, *, stride=1, padding="SAME",
             epilogue=None):
        from repro.kernels import halo_conv     # deferred: optional dep
        mesh, axis = self._mesh_axis(x, w_q.shape[0], stride, padding)
        if mesh is None:
            return get("pallas").conv(cfg, x, w_q, w_scale, stride=stride,
                                      padding=padding, epilogue=epilogue)
        y = halo_conv.sharded_trunk_conv(cfg, stride, padding, mesh, axis,
                                         x, w_q, w_scale)
        return base.finish(y, epilogue)


register("pallas_sharded", ShardedPallasEngine())
