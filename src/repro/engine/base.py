"""TrunkEngine: the execution contract every CiM backend implements.

YOLoC's premise is that ONE network runs on heterogeneous CiM substrates —
frozen ROM trunks, assisting SRAM branches, mapped per layer (paper §4,
Fig. 12) — so backend choice is data, not control flow.  A ``TrunkEngine``
is the pluggable unit of that choice: it owns the two frozen-trunk
primitives (matmul, conv) plus a capability record the registry gates on.

Engines receive the layer's ``CiMConfig`` (fidelity mode, ADC width,
subarray geometry) and the frozen int8 ROM image; they return float
outputs and are expected to provide a straight-through-estimator backward
(no dW — the ROM cannot be written).  The conv entry point additionally
takes a :class:`ConvEpilogue` so per-channel affine epilogues (bias, BN)
and the trailing activation can be folded into the trunk pass instead of
costing extra elementwise sweeps over the feature map.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax


@dataclasses.dataclass(frozen=True)
class EngineCapabilities:
    """What a backend can actually do — the registry gates requests on it.

    Enforced fields: ``fidelity_modes`` is gated by check() at resolve
    time; ``epilogue`` is consulted by the conv layers (engines without it
    are handed epilogue=None and the layer applies the affine/act itself).
    ``grads``/``devices`` are ADVISORY metadata for humans and tooling —
    resolve() cannot see whether it is inside a grad trace or which
    backend a trace will land on, so nothing gates on them.

    fidelity_modes: CiM modes the engine simulates; ``None`` means the
        engine is fidelity-agnostic (it ignores ``cfg.mode`` entirely,
        e.g. the dequantised float baseline).
    grads: whether the engine provides a (straight-through) backward.
    devices: JAX backends the engine runs on natively ('cpu'/'gpu'/'tpu');
        Pallas engines also run elsewhere in interpret mode, which the
        engine itself handles — this records where the fast path lives.
    epilogue: whether conv() honours a :class:`ConvEpilogue` (per-channel
        scale riding the trunk's dequant multiply, bias + activation in
        the same fused pass).
    sharded_ops: which primitives the engine runs natively under
        shard_map on a multi-device mesh ('matmul'/'conv'); empty means
        single-device (GSPMD still partitions around it).  ADVISORY like
        ``grads``/``devices`` — an op not listed is still correct, it
        just delegates or runs replicated.
    tune: whether the engine's kernels consult the ``repro.tune``
        tuning table for per-geometry tilings.  ``deploy.compile_model``
        gates its ``tune=True`` request on this; engines without it run
        fixed tilings and the flag request raises there.
    fused_ops: primitives with a fused trunk+branch fast path
        ('matmul'/'conv') — the layer routes a live-branch site through
        ``fused_conv``/``fused_matmul`` instead of trunk-op + separate
        branch convs when the op is listed (one pass over the shared
        im2col patch matrix; see kernels.rebranch_conv).
    """
    fidelity_modes: tuple | None = ("ideal", "per_subarray", "bitserial")
    grads: bool = True
    devices: tuple = ("cpu", "gpu", "tpu")
    epilogue: bool = False
    sharded_ops: tuple = ()
    tune: bool = False
    fused_ops: tuple = ()


@dataclasses.dataclass(frozen=True)
class ConvEpilogue:
    """Per-output-channel affine + activation fused after a trunk conv.

      y = act(conv(x, w) * scale + bias)

    ``scale``/``bias`` are [C_out] arrays (or None).  Inference BN folds
    exactly into this shape: scale = rsqrt(var+eps)*gamma, bias =
    beta - mean*scale.  The per-channel ``scale`` composes with the
    trunk's own dequantisation scales, so supporting engines apply it for
    free inside their existing scale epilogue.
    """
    scale: Any = None
    bias: Any = None
    act: str | None = None          # None | 'relu' | 'leaky_relu'
    leaky_slope: float = 0.1

    def without_act(self) -> "ConvEpilogue":
        return dataclasses.replace(self, act=None)


def activate(y, epilogue: ConvEpilogue | None):
    if epilogue is None or epilogue.act is None:
        return y
    if epilogue.act == "relu":
        return jax.nn.relu(y)
    if epilogue.act == "leaky_relu":
        return jax.nn.leaky_relu(y, epilogue.leaky_slope)
    raise ValueError(f"unknown epilogue activation: {epilogue.act!r}")


def finish(y, epilogue: ConvEpilogue | None):
    """scale -> bias -> activation tail of an epilogue, applied to the
    trunk output.  The per-channel scale rides the trunk's existing
    per-channel dequant multiply (XLA fuses the chain into one elementwise
    pass); applying it on the OUTPUT rather than pre-folding it into
    ``w_scale`` keeps BN parameters differentiable — ``w_scale`` is a
    nondiff argument of the STE custom_vjp, so anything folded into it
    would receive a float0 cotangent."""
    if epilogue is None:
        return y
    if epilogue.scale is not None:
        y = y * epilogue.scale.astype(y.dtype)
    if epilogue.bias is not None:
        y = y + epilogue.bias.astype(y.dtype)
    return activate(y, epilogue)


class TrunkEngine:
    """Base class for CiM trunk execution backends.

    Subclasses set ``name``/``capabilities`` and implement ``matmul`` and
    ``conv``.  Register instances with :func:`repro.engine.register`; layers
    obtain them with :func:`repro.engine.resolve`, which also enforces the
    capability contract against the requesting ``ReBranchSpec``.
    """

    name: str = "abstract"
    capabilities: EngineCapabilities = EngineCapabilities()

    def matmul(self, cfg, x, w_q, w_scale, *, out_axes=None):
        """y = dequant(CiM(quant(x), w_q)); [..., K] x [K, N] -> [..., N].

        out_axes: optional logical sharding annotation for the raw dot
        output (row-parallel reduce-scatter hint); engines without SPMD
        integration may ignore it.
        """
        raise NotImplementedError

    def conv(self, cfg, x, w_q, w_scale, *, stride=1, padding="SAME",
             epilogue: ConvEpilogue | None = None):
        """NHWC/HWIO frozen-trunk conv with an optional fused epilogue."""
        raise NotImplementedError

    def fused_matmul(self, cfg, x, w_q, w_scale, c, core, u):
        """Fused trunk+branch ReBranch matmul: one pass over x computes
        the CiM trunk dot AND the branch compress sketch.  Only engines
        listing 'matmul' in ``capabilities.fused_ops`` implement it."""
        raise NotImplementedError(
            f"engine {self.name!r} has no fused matmul path")

    def fused_conv(self, cfg, x, w_q, w_scale, c, core, u, *, stride=1,
                   padding="SAME", epilogue: ConvEpilogue | None = None):
        """Fused trunk+branch ReBranch conv sharing one im2col patch
        matrix; the epilogue (scale/bias/act) applies AFTER the branch
        add — act(BN(trunk + branch)) semantics.  Only engines listing
        'conv' in ``capabilities.fused_ops`` implement it."""
        raise NotImplementedError(
            f"engine {self.name!r} has no fused conv path")

    def check(self, spec) -> None:
        """Capability gate: raise if ``spec`` asks for something this
        engine cannot do (called by the registry's resolve())."""
        caps = self.capabilities
        mode = spec.cim.mode
        if caps.fidelity_modes is not None and mode not in caps.fidelity_modes:
            raise ValueError(
                f"engine {self.name!r} does not support CiM fidelity mode "
                f"{mode!r} (supported: {list(caps.fidelity_modes)}); pick "
                f"another mode or another engine")

    def __repr__(self):
        return f"<TrunkEngine {self.name!r} caps={self.capabilities}>"
