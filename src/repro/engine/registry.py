"""The TrunkEngine registry: named, pluggable CiM execution backends.

Backends register once under a string name; layers resolve the name from
``ReBranchSpec.trunk_impl`` at trace time.  Resolution is STRICT — an
unknown name raises immediately with the list of registered engines (no
silent fallback; a typo used to fall through to int8_native).
"""

from __future__ import annotations

from repro.engine.base import TrunkEngine

_REGISTRY: dict[str, TrunkEngine] = {}


def register(name: str, engine: TrunkEngine, *, override: bool = False):
    """Register ``engine`` under ``name``.

    Re-registering an existing name is an error unless ``override=True``
    (the hook for swapping in a tuned/sharded variant of a stock engine).
    Returns the engine so the call composes with construction.
    """
    if not isinstance(name, str) or not name:
        raise ValueError(f"engine name must be a non-empty str, got {name!r}")
    if name in _REGISTRY and not override:
        raise ValueError(
            f"engine {name!r} is already registered "
            f"({_REGISTRY[name]!r}); pass override=True to replace it")
    _REGISTRY[name] = engine
    return engine


def unregister(name: str) -> None:
    """Remove a registered engine (test/plugin teardown)."""
    _REGISTRY.pop(name, None)


def registered_names() -> list[str]:
    return sorted(_REGISTRY)


def get(name: str) -> TrunkEngine:
    """Strict name lookup: unknown names raise with the valid set."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown trunk engine {name!r}: registered engines are "
            f"{registered_names()}") from None


def resolve(spec_or_name) -> TrunkEngine:
    """Resolve a ``ReBranchSpec`` (via ``.trunk_impl``) or a bare name to
    its engine.  When given a spec, the engine's capability contract is
    enforced against it (fidelity mode etc.) — requesting e.g.
    ``bitserial`` from an engine that lacks it fails loudly here, not as
    a silent wrong-numerics forward."""
    if isinstance(spec_or_name, str):
        return get(spec_or_name)
    engine = get(spec_or_name.trunk_impl)
    engine.check(spec_or_name)
    return engine
