"""Pluggable CiM execution engines (the repo's backend seam).

One network, many substrates: every frozen-trunk matmul/conv in the repo
dispatches through a named :class:`TrunkEngine` resolved from
``ReBranchSpec.trunk_impl``.  The three stock engines (``int8_native``,
``dequant``, ``pallas``) register themselves on import; new backends (a
fused bitserial TPU kernel, a halo-exchange sharded conv, ...) plug in
with :func:`register` — no string surgery in core/models/kernels.

    from repro import engine
    engine.register("my_backend", MyEngine())
    spec = ReBranchSpec(trunk_impl="my_backend")

Resolution is strict (unknown names raise with the registered set) and
capability-gated (asking an engine for a fidelity mode it lacks fails
loudly).  ``repro.deploy.compile_model`` builds on this to map engines —
and ROM vs SRAM placement — per layer.
"""

from repro.engine.base import (
    ConvEpilogue, EngineCapabilities, TrunkEngine,
)
from repro.engine.registry import (
    get, register, registered_names, resolve, unregister,
)
from repro.engine import builtin as _builtin   # registers the stock engines

__all__ = [
    "ConvEpilogue", "EngineCapabilities", "TrunkEngine",
    "get", "register", "registered_names", "resolve", "unregister",
]
