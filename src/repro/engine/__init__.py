"""Pluggable CiM execution engines (the repo's backend seam).

One network, many substrates: every frozen-trunk matmul/conv in the repo
dispatches through a named :class:`TrunkEngine` resolved from
``ReBranchSpec.trunk_impl``.  The stock engines (``int8_native``,
``dequant``, ``pallas``, plus the halo-exchange ``pallas_sharded``)
register themselves on import; new backends (a fused bitserial TPU
kernel, ...) plug in with :func:`register` — no string surgery in
core/models/kernels.

    from repro import engine
    engine.register("my_backend", MyEngine())
    spec = ReBranchSpec(trunk_impl="my_backend")

Resolution is strict (unknown names raise with the registered set) and
capability-gated (asking an engine for a fidelity mode it lacks fails
loudly).  ``repro.deploy.compile_model`` builds on this to map engines —
and ROM vs SRAM placement — per layer.
"""

from repro.engine.base import (
    ConvEpilogue, EngineCapabilities, TrunkEngine,
)
from repro.engine.registry import (
    get, register, registered_names, resolve, unregister,
)
from repro.engine import builtin as _builtin   # registers the stock engines
from repro.engine import sharded as _sharded   # registers 'pallas_sharded'

__all__ = [
    "ConvEpilogue", "EngineCapabilities", "TrunkEngine",
    "get", "register", "registered_names", "resolve", "unregister",
]
