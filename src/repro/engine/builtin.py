"""The stock TrunkEngines, registered at import time.

int8_native : pure-jnp CiM macro model (core.cim) on int8 operands — the
              default; exact fidelity control, runs anywhere, what
              accuracy studies should use.
dequant     : dequantise the ROM image and run a plain XLA matmul/conv on
              fake-quantised activations — the paper-faithful float
              baseline the perf work is measured against.  Fidelity-
              agnostic (ignores ``cfg.mode``).
pallas      : the fused Pallas kernels (quantise in VMEM, int8 MXU dots,
              scale epilogue) — the TPU deployment fast path; interpret
              mode elsewhere.  Kernel import is deferred so environments
              without the Pallas toolchain can still use the other two.
pallas_fused: 'pallas' plus the fused trunk+branch kernels
              (rebranch_conv / rebranch_matmul) as first-class ops —
              live-branch sites compute trunk AND branch in one pass
              over the shared patch matrix.  Inference only (no STE
              backward on the fused paths).

Every engine's backward is the straight-through estimator (dx only, no
dW — the ROM cannot be written), so branch training is identical under
all three.
"""

from __future__ import annotations

from repro.core import rebranch as rebranch_lib
from repro.engine import base
from repro.engine.registry import register


class Int8NativeEngine(base.TrunkEngine):
    """core.cim macro model on int8 operands (all fidelity modes)."""

    name = "int8_native"
    capabilities = base.EngineCapabilities(
        fidelity_modes=("ideal", "per_subarray", "bitserial"),
        grads=True, devices=("cpu", "gpu", "tpu"), epilogue=True)

    def matmul(self, cfg, x, w_q, w_scale, *, out_axes=None):
        return rebranch_lib.trunk_matmul(cfg, out_axes, x, w_q, w_scale)

    def conv(self, cfg, x, w_q, w_scale, *, stride=1, padding="SAME",
             epilogue=None):
        y = rebranch_lib.trunk_conv(cfg, stride, padding, x, w_q, w_scale)
        return base.finish(y, epilogue)


class DequantEngine(base.TrunkEngine):
    """Dequantised float trunk + fake-quant activations (XLA baseline)."""

    name = "dequant"
    capabilities = base.EngineCapabilities(
        fidelity_modes=None,        # ignores cfg.mode entirely
        grads=True, devices=("cpu", "gpu", "tpu"), epilogue=True)

    def matmul(self, cfg, x, w_q, w_scale, *, out_axes=None):
        del out_axes                # plain XLA dot; GSPMD decides
        return rebranch_lib.trunk_matmul_dequant(cfg, x, w_q, w_scale)

    def conv(self, cfg, x, w_q, w_scale, *, stride=1, padding="SAME",
             epilogue=None):
        y = rebranch_lib.trunk_conv_dequant(cfg, stride, padding,
                                            x, w_q, w_scale)
        return base.finish(y, epilogue)


class PallasEngine(base.TrunkEngine):
    """Fused Pallas kernels (TPU fast path; interpret mode elsewhere)."""

    name = "pallas"
    capabilities = base.EngineCapabilities(
        fidelity_modes=("ideal", "per_subarray", "bitserial"),
        grads=True, devices=("tpu",), epilogue=True, tune=True)

    def matmul(self, cfg, x, w_q, w_scale, *, out_axes=None):
        from repro.kernels import ops as kops   # deferred: optional dep
        del out_axes                # kernel owns its own layout
        return kops.trunk_matmul_pallas(cfg, x, w_q, w_scale)

    def conv(self, cfg, x, w_q, w_scale, *, stride=1, padding="SAME",
             epilogue=None):
        from repro.kernels import ops as kops   # deferred: optional dep
        y = kops.trunk_conv(cfg, stride, padding, x, w_q, w_scale)
        return base.finish(y, epilogue)


class PallasFusedEngine(PallasEngine):
    """'pallas' plus the fused trunk+branch fast paths as first-class ops.

    Live-branch sites run ``kernels.rebranch_conv`` /
    ``kernels.rebranch_matmul`` — trunk macro dot AND branch compress
    sketch in ONE pass over the shared im2col patch matrix (the
    inference fast path the benchmarks race as 'fused').  Inference
    only: the fused kernels carry no STE custom_vjp, so ``grads=False``
    — training deployments should stay on 'pallas'.  Branchless sites
    and the epilogue contract are inherited unchanged from 'pallas'.
    """

    name = "pallas_fused"
    capabilities = base.EngineCapabilities(
        fidelity_modes=("ideal", "per_subarray", "bitserial"),
        grads=False, devices=("tpu",), epilogue=True, tune=True,
        fused_ops=("conv", "matmul"))

    def fused_matmul(self, cfg, x, w_q, w_scale, c, core, u):
        from repro.kernels import ops as kops   # deferred: optional dep
        lead = x.shape[:-1]         # kernel is 2D; flatten [..., K]
        y = kops.rebranch_matmul(x.reshape(-1, x.shape[-1]), w_q, w_scale,
                                 c, core, u, cfg)
        return y.reshape(*lead, y.shape[-1])

    def fused_conv(self, cfg, x, w_q, w_scale, c, core, u, *, stride=1,
                   padding="SAME", epilogue=None):
        from repro.kernels import ops as kops   # deferred: optional dep
        y = kops.rebranch_conv(x, w_q, w_scale, c, core, u,
                               stride=stride, padding=padding, cfg=cfg)
        return base.finish(y, epilogue)


register("int8_native", Int8NativeEngine())
register("dequant", DequantEngine())
register("pallas", PallasEngine())
register("pallas_fused", PallasFusedEngine())
