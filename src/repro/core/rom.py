"""ROM image utilities: the immutable trunk as a content-addressed artifact.

The ROM contents are fixed at "tape-out" (init / freeze time).  They are
never checkpointed — checkpoints store only the SRAM (trainable) state plus
the ROM fingerprint, and restore validates the fingerprint against the ROM
image the process booted with (paper: ROM is physically immutable, so
persisting it per-checkpoint would be waste; at 1000-node scale this cuts
checkpoint volume by ~16x together with the branch-only optimizer state).
"""

from __future__ import annotations

import hashlib

import jax
import numpy as np

from repro.core import rebranch


def rom_fingerprint(params) -> str:
    """SHA-256 over every ROM leaf (order-stable via sorted tree paths)."""
    _, frozen = rebranch.partition(params)
    h = hashlib.sha256()
    leaves = jax.tree_util.tree_flatten_with_path(frozen)[0]
    for path, leaf in sorted(leaves, key=lambda kv: jax.tree_util.keystr(kv[0])):
        if leaf is None:
            continue
        h.update(jax.tree_util.keystr(path).encode())
        arr = np.asarray(jax.device_get(leaf))
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def rom_bytes(params) -> int:
    """Total ROM image size in bytes (what would be mask-programmed)."""
    _, frozen = rebranch.partition(params)
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(frozen))


def sram_bytes(params) -> int:
    trainable, _ = rebranch.partition(params)
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(trainable))
