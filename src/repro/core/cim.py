"""Numerical model of the YOLoC ROM-CiM macro (paper §3.1, Fig. 5).

The macro is a 128x256 1T/cell ROM array: 128 word lines (inputs) x 256
bit lines.  An 8-bit weight occupies 8 binary bit-plane columns; serial
activation bits are applied on the WLs (2-bit unary-pulse groups, "0,1,2,
or 3 pulses"); the bit-line charge — the count of conducting cells — is
digitised by a column-shared **5-bit ADC** and recombined digitally by
shift-add.  Signed operands use offset-binary encoding (u = q + 128) with
exact digital correction terms, the standard CiM practice.

Three fidelity modes:
  'ideal'        : exact int8 matmul (ADC with infinite resolution) — the
                   deployment fast path (plain MXU int8 dot).
  'per_subarray' : partial sums over each 128-row subarray pass through the
                   ADC transfer function once (captures the dominant
                   quantisation nonlinearity; cheap enough for training).
  'bitserial'    : the full model — activation 2-bit unary groups x weight
                   bit planes x subarrays, each analogue count ADC-quantised
                   (paper-faithful; used for accuracy studies + kernel oracle).

This module is pure jnp; kernels/ref.py re-uses it as the Pallas oracle.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import adc as adc_lib


@dataclasses.dataclass(frozen=True)
class CiMConfig:
    rows_per_subarray: int = 128   # WLs summed on one bit line
    adc_bits: int = 5              # paper: 16 column-shared 5-bit ADCs
    act_bits: int = 8              # Table I: 8-bit activations
    weight_bits: int = 8           # Table I: 8-bit weights
    act_group_bits: int = 2        # unary pulse groups: 0..3 pulses per WL
    # ADC input range as a fraction of the achievable bit-line count
    # (popcount-matched per column since ROM contents are tape-out-known).
    # 0.5 is the engineered sweet spot: ~6% rms error of output std,
    # tightened further by branch adaptation (QAT) during transfer.
    adc_range_frac: float = 0.5
    # per_subarray mode: signed partial-sum swing fraction (differential).
    psum_range_frac: float = 1.0
    mode: str = "per_subarray"     # 'ideal' | 'per_subarray' | 'bitserial'

    @property
    def adc_levels(self) -> int:
        return (1 << self.adc_bits) - 1

    @property
    def act_groups(self) -> int:
        return self.act_bits // self.act_group_bits

    @property
    def group_max(self) -> int:
        return (1 << self.act_group_bits) - 1


DEFAULT_CIM = CiMConfig()


# ADC transfer functions live in core.adc (shared verbatim with the Pallas
# kernels); re-exported here for callers/tests that address them as cim.*.
adc_transfer = adc_lib.adc_transfer
_signed_adc = adc_lib.signed_adc


def _pad_to_subarrays(a_q: jax.Array, w_q: jax.Array, rows: int):
    k = a_q.shape[-1]
    pad = (-k) % rows
    if pad:
        a_q = jnp.pad(a_q, [(0, 0)] * (a_q.ndim - 1) + [(0, pad)])
        w_q = jnp.pad(w_q, [(0, pad), (0, 0)])
    return a_q, w_q, (k + pad) // rows


def cim_matmul_model(
    a_q: jax.Array,          # int8 [..., K] quantised activations
    w_q: jax.Array,          # int8 [K, N] quantised weights (ROM contents)
    cfg: CiMConfig = DEFAULT_CIM,
) -> jax.Array:
    """Integer-domain CiM matmul model: returns int32-valued f32 [..., N].

    Output approximates ``a_q @ w_q``; callers apply float scales outside.
    """
    if cfg.mode == "ideal":
        return jax.lax.dot_general(
            a_q, w_q, (((a_q.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32)
    if cfg.mode == "per_subarray":
        return _per_subarray_model(a_q, w_q, cfg)
    if cfg.mode == "bitserial":
        return _bitserial_model(a_q, w_q, cfg)
    raise ValueError(f"unknown CiM mode: {cfg.mode!r}")


def _per_subarray_model(a_q, w_q, cfg: CiMConfig) -> jax.Array:
    """Signed per-subarray partial sums through the ADC."""
    rows = cfg.rows_per_subarray
    a_q, w_q, s = _pad_to_subarrays(a_q, w_q, rows)
    batch = a_q.shape[:-1]
    a3 = a_q.reshape(*batch, s, rows).astype(jnp.float32)
    w3 = w_q.reshape(s, rows, w_q.shape[-1]).astype(jnp.float32)
    # [..., s, N] partial sums per subarray
    psums = jnp.einsum("...sr,srn->...sn", a3, w3)
    # Analogue swing engineered to the typical range:  rows * 127 (one
    # full-scale operand); worst case is rows * 127 * 127 but real partial
    # sums never reach it, matching the paper's <7% error peripherals.
    full_range = rows * 127.0
    psums = _signed_adc(psums, full_range, cfg)
    return jnp.sum(psums, axis=-2)


def _bitserial_model(a_q, w_q, cfg: CiMConfig) -> jax.Array:
    """Paper-faithful bit-serial model with differential (sign-split) arrays.

    Signed operands are realised the way CiM macros do it — positive and
    negative cell arrays sensed differentially:  a = a+ - a-,  w = w+ - w-
    (magnitudes in [0,127]).  This preserves bit-plane *sparsity*: for
    realistic (concentrated) weight/activation distributions the high-order
    planes are almost entirely zero, so the 5-bit ADC error lands on the
    low-amplification planes — this is why the paper sees ~no accuracy loss.

      A(a', w') = sum_s sum_g sum_j 4^g 2^j ADC( sum_{k in s} a'_g[k] w'_j[k,n] )
      out       = A(a+,w+) - A(a+,w-) - A(a-,w+) + A(a-,w-)

    (g: 2-bit unary activation groups — "0,1,2,3 pulses"; j: weight bit
    planes across columns; s: 128-row subarrays.)
    """
    rows = cfg.rows_per_subarray
    a_q, w_q, s = _pad_to_subarrays(a_q, w_q, rows)
    batch = a_q.shape[:-1]
    n = w_q.shape[-1]

    a_i = a_q.astype(jnp.int32)
    w_i = w_q.astype(jnp.int32)
    a_split = (jnp.maximum(a_i, 0), jnp.maximum(-a_i, 0))
    w_split = (jnp.maximum(w_i, 0), jnp.maximum(-w_i, 0))

    mag_bits, act_groups, group_max = adc_lib.bitserial_planes(cfg)

    acc = jnp.zeros((*batch, n), jnp.float32)
    for sa, a_part in enumerate(a_split):
        a3 = a_part.reshape(*batch, s, rows)
        for sw, w_part in enumerate(w_split):
            sign = 1.0 if sa == sw else -1.0
            w3 = w_part.reshape(s, rows, n)
            for g in range(act_groups):
                a_g = ((a3 >> (g * cfg.act_group_bits)) & group_max
                       ).astype(jnp.float32)
                for j in range(mag_bits):
                    w_j = ((w3 >> j) & 1).astype(jnp.float32)
                    counts = jnp.einsum("...sr,srn->...sn", a_g, w_j)
                    # ROM co-design: the mask contents are known at tape-out,
                    # so each column's sense reference is matched to the
                    # number of programmed cells on that bit line — the
                    # achievable count is popcount*group_max, not rows*group_max.
                    popcount = jnp.sum(w_j, axis=-2)            # [s, n]
                    full_range = jnp.maximum(popcount * group_max, 1.0)
                    sensed = adc_transfer(counts, full_range, cfg)
                    acc = acc + sign * (4.0 ** g) * (2.0 ** j) * jnp.sum(
                        sensed, axis=-2)
    return acc


# ---------------------------------------------------------------------------
# Convolution on the macro: im2col lowering (paper §4.1 CNN workloads)
# ---------------------------------------------------------------------------

def conv_pads(size: int, k: int, stride: int, padding: str):
    """XLA-compatible (lo, hi) padding and output size for one spatial dim."""
    if padding == "VALID":
        assert size >= k, f"VALID conv needs size >= kernel ({size} < {k})"
        return (0, 0), (size - k) // stride + 1
    if padding != "SAME":
        raise ValueError(f"unknown padding: {padding!r}")
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return (total // 2, total - total // 2), out


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           padding: str = "SAME"):
    """Extract conv patches: NHWC -> ([N, OH, OW, kh*kw*C], (OH, OW)).

    Column order matches ``w.reshape(kh*kw*C, c_out)`` of an HWIO kernel —
    taps row-major, input channels fastest — so
    ``conv(x, w) == im2col(x)[0] @ w.reshape(-1, c_out)`` exactly.
    Zero padding (conv semantics); dtype-preserving, so int8 ROM operands
    stay int8 all the way to the macro.
    """
    _, h, w_sz, _ = x.shape
    (ph0, ph1), oh = conv_pads(h, kh, stride, padding)
    (pw0, pw1), ow = conv_pads(w_sz, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    taps = [
        xp[:, i:i + (oh - 1) * stride + 1:stride,
           j:j + (ow - 1) * stride + 1:stride, :]
        for i in range(kh) for j in range(kw)
    ]
    return jnp.concatenate(taps, axis=-1), (oh, ow)


def cim_conv_model(
    x_q: jax.Array,          # int8 [N, H, W, C_in] quantised activations
    w_q: jax.Array,          # int8 [KH, KW, C_in, C_out] ROM contents
    cfg: CiMConfig = DEFAULT_CIM,
    stride: int = 1,
    padding: str = "SAME",
) -> jax.Array:
    """Integer-domain CiM convolution model: f32 [N, OH, OW, C_out].

    im2col through :func:`cim_matmul_model`, so every fidelity mode
    ('ideal' / 'per_subarray' / 'bitserial') applies unchanged; this is
    the golden reference the Pallas conv kernels are tested against.
    """
    kh, kw, c_in, c_out = w_q.shape
    patches, _ = im2col(x_q, kh, kw, stride, padding)
    return cim_matmul_model(patches, w_q.reshape(kh * kw * c_in, c_out), cfg)


def macro_count(weights: int, cfg: CiMConfig = DEFAULT_CIM,
                cols: int = 256) -> int:
    """How many 128x256 macros hold ``weights`` 8-bit weights (bit-planed)."""
    cells_per_macro = cfg.rows_per_subarray * cols
    bits = weights * cfg.weight_bits
    return -(-bits // cells_per_macro)
