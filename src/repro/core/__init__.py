"""Core: the paper's contribution — ROM-CiM + ReBranch — as JAX modules."""

from repro.core.cim import (
    CiMConfig, cim_matmul_model, cim_conv_model, im2col, adc_transfer,
    macro_count,
)
from repro.core.rebranch import (
    ReBranchSpec, init_linear, apply_linear, partition, combine,
    trainable_count, frozen_count, trunk_matmul, trunk_conv, freeze_to_rom,
)
from repro.core.rom import rom_fingerprint, rom_bytes, sram_bytes
from repro.core import energy, quant

__all__ = [
    "CiMConfig", "cim_matmul_model", "cim_conv_model", "im2col",
    "adc_transfer", "macro_count",
    "ReBranchSpec", "init_linear", "apply_linear", "partition", "combine",
    "trainable_count", "frozen_count", "trunk_matmul", "trunk_conv",
    "freeze_to_rom",
    "rom_fingerprint", "rom_bytes", "sram_bytes", "energy", "quant",
]
