"""The 5-bit ADC transfer functions of the ROM-CiM macro (paper §3.1).

One home for the analogue-to-digital math that every CiM execution path
shares: the pure-jnp macro model (core.cim) and the Pallas kernels
(kernels.cim_matmul, and through its ``cim_block_dot`` the fused conv
kernels in kernels.rebranch_conv) all import THESE functions, so the
comparator-threshold convention can never drift between model and kernel.

Everything here is plain jnp on values already resident in registers /
VMEM — safe both at the XLA level and inside a Pallas kernel body.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Comparator thresholds are deterministic and biased a hair below the
# half-step, so integer counts landing exactly on a half boundary resolve
# identically in every implementation (model & kernel float pipelines).
THRESHOLD_BIAS = 1e-3


def adc_transfer(psum: jax.Array, full_range, cfg) -> jax.Array:
    """5-bit ADC: quantise a non-negative analogue count to 2^B levels.

    The bit line is pre-charged and discharged by conducting cells, so the
    quantity sensed is a count in [0, full_range] (scalar or per-column
    array — ROM contents are tape-out-known, so references are per-column);
    the ADC maps it to ``cfg.adc_levels`` uniform steps, clipping above
    the engineered range.
    """
    rng = full_range * cfg.adc_range_frac
    lsb = rng / cfg.adc_levels
    code = jnp.clip(jnp.round(psum / lsb + THRESHOLD_BIAS),
                    0, cfg.adc_levels)
    return code * lsb


def signed_adc(psum: jax.Array, full_range, cfg) -> jax.Array:
    """ADC transfer for signed per-subarray partial sums (per_subarray mode).

    Differential sensing (positive/negative weight columns) yields a signed
    swing of +-full_range digitised by the same 2^B-level ADC.
    """
    rng = full_range * cfg.psum_range_frac
    half_levels = cfg.adc_levels / 2.0
    lsb = rng / half_levels
    code = jnp.clip(jnp.round(psum / lsb + THRESHOLD_BIAS),
                    -half_levels, half_levels)
    return code * lsb


def bitserial_planes(cfg) -> tuple[int, int, int]:
    """(weight magnitude bit planes, activation pulse groups, group max)
    for the differential bit-serial decomposition — shared by the model
    and the kernel so both iterate the exact same plane set."""
    mag_bits = cfg.weight_bits - 1              # |w| <= 127 -> 7 planes
    act_groups = -(-(cfg.act_bits - 1) // cfg.act_group_bits)
    return mag_bits, act_groups, cfg.group_max
