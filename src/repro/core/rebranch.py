"""ReBranch (paper §3.2, Fig. 7): frozen ROM trunk + small trainable branch.

    y = Trunk_ROM(x) + Decompress(ResCore(Compress(x))) (+ bias)

* Trunk: int8 weights + per-channel scales, physically immutable ("ROM").
* Compress ``C``  (d_in  -> d_in//D)  : fixed point-wise projection (ROM).
* ResCore ``core``(d_in//D -> d_out//U): the ONLY trainable tensor ("SRAM").
* Decompress ``U``(d_out//U -> d_out) : fixed point-wise projection (ROM).

With the paper's optimum D=U=4 the branch holds 1/16 of the trunk's
parameters (Fig. 11).  ``core`` is zero-initialised so a freshly-frozen
model is exactly the pretrained model (branch contributes 0).

Parameter convention: every pytree whose dict key is ``"rom"`` is frozen —
excluded from autodiff, optimizer state, gradient collectives and
checkpoints.  ``partition``/``combine`` implement that split.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim as cim_lib
from repro.core import quant

ROM_KEY = "rom"


@dataclasses.dataclass(frozen=True)
class ReBranchSpec:
    d_ratio: int = 4                 # compression ratio D (paper Fig. 11)
    u_ratio: int = 4                 # decompression ratio U
    enabled: bool = True             # False -> plain trainable linear ("SRAM")
    # Trunk execution backend: any name in the repro.engine registry
    # ('int8_native' | 'dequant' | 'pallas' out of the box).  Resolution
    # is strict — unknown names raise with the registered set.
    trunk_impl: str = "int8_native"
    cim: cim_lib.CiMConfig = dataclasses.field(
        default_factory=lambda: cim_lib.CiMConfig(mode="ideal"))
    param_dtype: Any = jnp.float32   # branch/scale dtype
    branch_enabled: bool = True      # trunk-only (frozen, no adapter) if False
    # Speculative-draft mode: skip the ROM trunk matmul entirely and run
    # only the SRAM-resident branch (y = (x@C)@(core@U) + b).  The output
    # approximates the full layer at ~1/compression of the FLOPs — the
    # draft half of draft/verify speculative decoding (serve spec mode).
    # Never used for training or verified serving output.
    trunk_skip: bool = False

    @property
    def compression(self) -> int:
        return self.d_ratio * self.u_ratio


# ---------------------------------------------------------------------------
# pytree partitioning: ROM (frozen) vs SRAM (trainable)
# ---------------------------------------------------------------------------

def _is_none(x) -> bool:
    return x is None


def partition(params):
    """Split params into (trainable, frozen) trees; non-members are None."""
    def walk(node, in_rom):
        if isinstance(node, dict):
            train, froz = {}, {}
            for k, v in node.items():
                t, f = walk(v, in_rom or k == ROM_KEY)
                train[k], froz[k] = t, f
            return train, froz
        if isinstance(node, (list, tuple)):
            typ = type(node)
            if typ in (list, tuple):
                pairs = [walk(v, in_rom) for v in node]
                return typ(p[0] for p in pairs), typ(p[1] for p in pairs)
            if hasattr(node, "_fields"):          # namedtuple
                pairs = [walk(v, in_rom) for v in node]
                return (typ(*(p[0] for p in pairs)),
                        typ(*(p[1] for p in pairs)))
            # other tuple subclasses (e.g. jax.sharding.PartitionSpec) are
            # pytree LEAVES in jax.tree semantics — do not recurse/rebuild
        return (None, node) if in_rom else (node, None)

    return walk(params, False)


def combine(trainable, frozen):
    """Inverse of :func:`partition`."""
    return jax.tree.map(
        lambda a, b: a if a is not None else b,
        trainable, frozen, is_leaf=_is_none)


def trainable_count(params) -> int:
    t, _ = partition(params)
    return sum(x.size for x in jax.tree.leaves(t))


def frozen_count(params) -> int:
    _, f = partition(params)
    return sum(x.size for x in jax.tree.leaves(f))


# ---------------------------------------------------------------------------
# Trunk matmul: frozen int8 path with a straight-through backward
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def trunk_matmul(cfg: cim_lib.CiMConfig, out_axes, x, w_q, w_scale):
    """y = CiM(quantize(x), w_q) * (sx * w_scale);  frozen-weight matmul.

    Forward runs the (possibly non-ideal) CiM model on int8 operands;
    backward is the straight-through estimator  dx = g @ dequant(w)^T.
    No dW is ever produced (the ROM cannot be written).

    out_axes (static, optional): logical sharding annotation placed on the
    RAW dot output (and on dx in the backward) so the SPMD partitioner can
    turn row-parallel partial-sum all-reduces into reduce-scatters.
    """
    x_q, sx = quant.quantize_activations(x)
    out = cim_lib.cim_matmul_model(x_q, w_q, cfg)
    if out_axes is not None:
        from repro.distributed.sharding import shard
        out = shard(out, *out_axes)
    return (out * sx).astype(x.dtype) * w_scale.astype(x.dtype)


def _trunk_fwd(cfg, out_axes, x, w_q, w_scale):
    return trunk_matmul(cfg, out_axes, x, w_q, w_scale), (w_q, w_scale)


def _trunk_bwd(cfg, out_axes, res, g):
    w_q, w_scale = res
    w_deq = w_q.astype(g.dtype) * w_scale.astype(g.dtype)   # [K, N]
    dx = jnp.einsum("...n,kn->...k", g, w_deq)
    if out_axes is not None:
        # bwd of a column-parallel trunk is row-parallel: same RS rewrite
        from repro.distributed.sharding import shard
        dx = shard(dx, *out_axes)
    zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, zero(w_q), zero(w_scale)


trunk_matmul.defvjp(_trunk_fwd, _trunk_bwd)


def conv_nhwc(x, w, stride: int = 1, padding: str = "SAME"):
    """The repo's one NHWC/HWIO conv wrapper (models and oracles reuse it)."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def trunk_conv_residuals(x, w_q, w_scale):
    """Residuals for the conv-trunk STE backward (shared by the
    int8_native path here and the Pallas dispatch in kernels/ops.py).

    zeros_like(x) carries only shape/dtype into the backward (the conv is
    linear in x, so its vjp never reads the primal values); XLA DCEs it.
    """
    return (w_q, w_scale, jnp.zeros_like(x))


def trunk_conv_ste_bwd(stride: int, padding: str, res, g):
    """Shared STE backward: dx = conv_transpose(g, dequant(w)), no dW."""
    w_q, w_scale, x0 = res
    w_deq = w_q.astype(g.dtype) * w_scale.reshape(1, 1, 1, -1).astype(g.dtype)
    dx = jax.vjp(lambda t: conv_nhwc(t, w_deq, stride, padding), x0)[1](g)[0]
    zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, zero(w_q), zero(w_scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def trunk_conv(cfg: cim_lib.CiMConfig, stride: int, padding: str,
               x, w_q, w_scale):
    """Conv analogue of :func:`trunk_matmul`: frozen int8 ROM trunk conv.

    Forward im2cols the NHWC input, quantises each patch row dynamically
    and runs the (possibly non-ideal) CiM macro model on the patch matrix;
    backward is the straight-through estimator
    ``dx = conv_transpose(g, dequant(w))``.  No dW is ever produced.

    x: [N, H, W, C_in] float;  w_q: [KH, KW, C_in, C_out] int8;
    w_scale: per-output-channel f32 (any shape reducible to [C_out]).
    """
    kh, kw, c_in, c_out = w_q.shape
    patches, _ = cim_lib.im2col(x, kh, kw, stride, padding)
    p_q, sp = quant.quantize_activations(patches)
    out = cim_lib.cim_matmul_model(p_q, w_q.reshape(kh * kw * c_in, c_out),
                                   cfg)
    return (out * sp).astype(x.dtype) * w_scale.reshape(-1).astype(x.dtype)


def _trunk_conv_fwd(cfg, stride, padding, x, w_q, w_scale):
    out = trunk_conv(cfg, stride, padding, x, w_q, w_scale)
    return out, trunk_conv_residuals(x, w_q, w_scale)


def _trunk_conv_bwd(cfg, stride, padding, res, g):
    return trunk_conv_ste_bwd(stride, padding, res, g)


trunk_conv.defvjp(_trunk_conv_fwd, _trunk_conv_bwd)


def trunk_matmul_dequant(cfg, x, w_q, w_scale):
    """Paper-faithful *baseline* trunk path: dequantise to bf16/f32 and use a
    dense matmul with fake-quantised activations (STE built in).  2x the
    weight HBM traffic of the int8-native path; kept as the reference the
    §Perf optimization is measured against."""
    del cfg
    x_hq = quant.fake_quant_ste(x)
    w = w_q.astype(x.dtype) * w_scale.astype(x.dtype)
    return x_hq @ w


def trunk_conv_dequant(cfg, stride: int, padding: str, x, w_q, w_scale):
    """Conv analogue of :func:`trunk_matmul_dequant`: dequantised weights +
    fake-quantised activations on a plain XLA conv (STE built in)."""
    del cfg
    w = w_q.astype(x.dtype) * w_scale.astype(x.dtype)
    return conv_nhwc(quant.fake_quant_ste(x), w, stride, padding)


# ---------------------------------------------------------------------------
# ReBranch linear layer
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, spec: ReBranchSpec,
                *, w_init: jax.Array | None = None,
                use_bias: bool = False, name_scale: float = 1.0):
    """Create ReBranch linear params.

    If ``w_init`` is given the trunk ROM image is built from it (freeze a
    pretrained matrix); otherwise the trunk is randomly initialised and
    frozen (pretraining-from-scratch is done *before* freezing, see
    examples/transfer_rebranch.py).
    """
    kw, kc, ku = jax.random.split(key, 3)
    dt = spec.param_dtype
    if w_init is None:
        w_init = jax.random.normal(kw, (d_in, d_out), dt)
        w_init = w_init * (name_scale / np.sqrt(d_in))
    if not spec.enabled:
        p = {"sram": {"w": w_init.astype(dt)}}
        if use_bias:
            p["sram"]["b"] = jnp.zeros((d_out,), dt)
        return p

    w_q, w_scale = quant.quantize_weights(w_init, axis=0)
    rom = {"w_q": w_q, "w_scale": w_scale.astype(dt)}
    p = {"rom": rom, "sram": {}}
    if spec.branch_enabled:
        d_c = max(1, d_in // spec.d_ratio)
        d_u = max(1, d_out // spec.u_ratio)
        # Fixed (ROM) projections: scaled Gaussian — an oblivious JL-style
        # sketch; frozen at "tape-out".
        rom["C"] = (jax.random.normal(kc, (d_in, d_c), dt) / np.sqrt(d_in))
        rom["U"] = (jax.random.normal(ku, (d_u, d_out), dt) / np.sqrt(d_u))
        p["sram"]["core"] = jnp.zeros((d_c, d_u), dt)   # branch starts at 0
    if use_bias:
        p["sram"]["b"] = jnp.zeros((d_out,), dt)
    return p


def apply_linear(params, x, spec: ReBranchSpec, t1_axes=None,
                 out_axes=None):
    """Apply a ReBranch linear layer (or a plain linear if disabled).

    t1_axes: optional logical-axis annotation for the branch compress
    output.  Row-parallel trunks (o/down projections) pass
    ('batch','seq','mlp') so GSPMD reduce-scatters t1 instead of
    all-reducing + re-gathering the d_in/D-wide intermediate.
    out_axes: optional constraint applied DIRECTLY to the trunk matmul
    output (before the branch add) — placing it adjacent to the dot lets
    the SPMD partitioner turn the row-parallel partial-sum all-reduce
    into a reduce-scatter.
    """
    if not spec.enabled:
        y = x @ params["sram"]["w"].astype(x.dtype)
        b = params["sram"].get("b")
        return y if b is None else y + b.astype(x.dtype)

    rom, sram = params["rom"], params["sram"]
    if spec.trunk_skip:
        # Draft path (speculative decode): the ROM trunk never runs —
        # only the SRAM-resident branch contributes, at ~1/compression
        # of the layer's FLOPs.  No engine resolution either: the draft
        # is pure XLA on the branch tensors.  Branchless ROM sites
        # contribute zero (their whole signal lives in the trunk).
        if spec.branch_enabled and "core" in sram:
            c = rom["C"].astype(x.dtype)
            u = rom["U"].astype(x.dtype)
            core = sram["core"].astype(x.dtype)
            y = (x @ c) @ (core @ u)
        else:
            y = jnp.zeros((*x.shape[:-1], rom["w_q"].shape[-1]), x.dtype)
        b = sram.get("b")
        return y if b is None else y + b.astype(x.dtype)
    from repro import engine as engine_lib   # deferred: avoids import cycle
    eng = engine_lib.resolve(spec)           # strict + capability-gated
    if (spec.branch_enabled and "core" in sram
            and "matmul" in eng.capabilities.fused_ops):
        # fused trunk+branch pass: one read of x computes the CiM dot and
        # the compress sketch (t1_axes/out_axes hints don't apply — the
        # fused kernel owns its own layout)
        y = eng.fused_matmul(spec.cim, x, rom["w_q"], rom["w_scale"],
                             rom["C"], sram["core"], rom["U"])
        b = sram.get("b")
        return y if b is None else y + b.astype(x.dtype)
    y = eng.matmul(spec.cim, x, rom["w_q"], rom["w_scale"],
                   out_axes=out_axes)

    if spec.branch_enabled and "core" in sram:
        c = rom["C"].astype(x.dtype)
        u = rom["U"].astype(x.dtype)
        core = sram["core"].astype(x.dtype)
        # Reassociated epilogue: (x@C) @ (core@U).  core@U is a tiny
        # [d_in/D, d_out] precompute whose output sharding matches the
        # trunk's, so the branch adds NO collectives and NO wide
        # intermediate activation ((t1@core)@U would materialise a
        # d_out/U-wide tensor and force an all-gather under TP).
        t1 = x @ c
        if t1_axes is not None:
            from repro.distributed.sharding import shard
            t1 = shard(t1, *t1_axes)
        y = y + t1 @ (core @ u)
    b = sram.get("b")
    return y if b is None else y + b.astype(x.dtype)


def freeze_to_rom(params_dense, key, spec: ReBranchSpec):
    """Convert a tree of plain linears ({'sram': {'w': ..}}) into ReBranch
    form — the 'tape-out' step: quantise trunks into ROM, attach branches."""
    def conv(path, node):
        if isinstance(node, dict) and "sram" in node and "w" in node.get("sram", {}):
            w = node["sram"]["w"]
            sub = jax.random.fold_in(key, hash(path) % (2 ** 31))
            p = init_linear(sub, w.shape[0], w.shape[1], spec, w_init=w,
                            use_bias="b" in node["sram"])
            if "b" in node["sram"]:
                p["sram"]["b"] = node["sram"]["b"]
            return p
        if isinstance(node, dict):
            return {k: conv(path + (k,), v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(conv(path + (i,), v) for i, v in enumerate(node))
        return node
    return conv((), params_dense)
