"""Symmetric int8 quantization utilities for the ROM/SRAM-CiM split.

The paper stores 8-bit weights in ROM-CiM (Table I: "Input x weight:
8-bit x 8-bit").  On TPU the analogue is int8 storage + per-output-channel
float scales.  Activations are dynamically quantized per row (per token)
with a straight-through estimator so gradients flow to the branch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def quantize_weights(w: jax.Array, axis: int = 0) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-channel int8 quantization.

    Args:
      w: float weights, any shape.
      axis: the *contraction* axis; scales are computed over it so each
        output channel keeps its own scale (reduces over ``axis``).

    Returns:
      (w_q int8, scale f32) with ``w ≈ w_q * scale`` (scale broadcastable).
    """
    absmax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / INT8_MAX
    w_q = jnp.clip(jnp.round(w / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return w_q, scale.astype(jnp.float32)


def dequantize(w_q: jax.Array, scale: jax.Array) -> jax.Array:
    return w_q.astype(scale.dtype) * scale


def quantize_activations(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dynamic symmetric per-row (last-axis-reduced) int8 quantization."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / INT8_MAX
    x_q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return x_q, scale


def quant_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reciprocal-form variant of :func:`quantize_activations`.

    Same quantisation scheme as ``quantize_activations`` but with every
    division replaced by a reciprocal multiply: the rounded integers
    stay in range because ``|x| * (1/scale) <= 127 * (1 + O(eps))``
    never reaches the .5 rounding boundary at 127.5.  Two reasons for
    the reciprocal form: XLA:CPU emits a vectorised multiply where the
    division form stalls (this is what makes the fused kernels
    competitive), and — crucially for the sharded bit-parity contracts
    — jitted XLA rewrites division *by a constant* into a reciprocal
    multiply anyway (1 ulp off the true quotient), so writing the
    multiply out explicitly is the only way eager and jitted callers
    agree bit-for-bit.
    """
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) * (1.0 / INT8_MAX)
    x_q = jnp.clip(jnp.round(x * (1.0 / scale)),
                   -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return x_q, scale


def quant_rows_f32(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Like :func:`quant_rows` but keeps the quantized values in f32.

    The clip is unnecessary: the per-row absmax bounds
    ``|x| * (1/scale)`` by ``127 * (1 + O(eps)) < 127.01`` which rounds
    to at most 127, so the rounded product already lies in
    ``[-127, 127]``.  Skipping the int8 round-trip keeps the values in
    the f32 GEMM sweet spot on CPU.
    """
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) * (1.0 / INT8_MAX)
    return jnp.round(x * (1.0 / scale)), scale


def fake_quant_ste(x: jax.Array) -> jax.Array:
    """Fake-quantize activations with a straight-through gradient."""
    x_q, scale = quantize_activations(x)
    x_hat = x_q.astype(x.dtype) * scale.astype(x.dtype)
    return x + jax.lax.stop_gradient(x_hat - x)


@functools.partial(jax.jit, static_argnames=("preferred",))
def int8_matmul(x_q: jax.Array, w_q: jax.Array, preferred=jnp.int32) -> jax.Array:
    """Native int8 x int8 -> int32 matmul (MXU int8 path on TPU)."""
    return jax.lax.dot_general(
        x_q, w_q,
        dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=preferred,
    )
