"""System-level area / energy / latency cost model (paper §4.3, Figs. 12-14).

Reproduces the paper's evaluation methodology:

  * Macro constants from Table I (ROM-CiM: 5 Mb/mm^2, 11.5 TOPS/W, 28.8
    GOPS & 8.9 ns per 128x256 macro; ROM cell 0.014 um^2; SRAM-CiM 19x less
    dense at system level).
  * DRAM read energy / bandwidth in the CACTI(-IO) range (the paper uses
    CACTI [24]; exact configs unpublished).
  * Chiplet interconnect energy from SIMBA [25]: 1.17 pJ/b.

Three system configurations (Fig. 13):
  (a) YOLoC  : trunk in ROM-CiM + branch in SRAM-CiM, no DRAM weight traffic.
  (b) single : iso-area all-SRAM-CiM chip; weights beyond on-chip capacity
               stream from DRAM every inference.
  (c) chiplet: enough SRAM-CiM chiplets to hold all weights; inter-chip
               feature traffic pays the SIMBA link energy.

Calibration note (documented, honest): the paper's SPICE/CACTI component
values are not published.  Constants marked CALIBRATED below were fit once
(benchmarks/fig14_system_energy.py --calibrate) inside their published
ranges so the model reproduces the paper's headline ratios (4.8x ResNet-18,
10.2x Tiny-YOLO, 14.8x YOLO); everything else is from Table I verbatim.
The *structure* of every term follows the paper.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class CostModel:
    # ---- Table I (verbatim) ----
    rom_density_mb_mm2: float = 5.0          # ROM-CiM system density
    rom_tops_w: float = 11.5                 # 8b x 8b MAC efficiency
    macro_gops: float = 28.8                 # per 128x256 macro
    macro_bits: float = 1.2e6                # 1.2 Mb per macro
    sram_density_ratio: float = 19.0         # ROM is 19x denser (system)
    # ---- literature-range constants ----
    sram_tops_w: float = 1.68                # CALIBRATED: 8b SRAM-CiM system
    #   level ([3]-peripheral class); fixed by the ResNet-18 4.8x anchor.
    #   Reflects the reload-stalled single chip / small branch arrays.
    sram_macro_tops_w: float = 8.73          # CALIBRATED: macro-level SRAM-
    #   CiM efficiency with resident weights (chiplet config); consistent
    #   with "peripherals from [3]" being shared with the 11.5 TOPS/W ROM.
    dram_pj_per_bit: float = 24.2            # CALIBRATED in CACTI DDR4 range
    dram_gbps: float = 25.6                  # LPDDR4-class bandwidth (GB/s)
    link_pj_per_bit: float = 1.17            # SIMBA [25], verbatim
    sram_cache_pj_per_bit: float = 0.08      # on-chip buffer access
    chiplet_bits: float = 150e6              # SRAM-CiM chiplet capacity
    weight_bits: int = 8
    act_bits: int = 8

    # derived
    @property
    def sram_density_mb_mm2(self) -> float:
        return self.rom_density_mb_mm2 / self.sram_density_ratio

    @property
    def rom_pj_per_mac(self) -> float:
        return 2.0 / self.rom_tops_w        # 1 MAC = 2 OPS

    @property
    def sram_pj_per_mac(self) -> float:
        return 2.0 / self.sram_tops_w


DEFAULT_COST = CostModel()


@dataclasses.dataclass(frozen=True)
class NetStats:
    """Workload description (computed from the actual JAX model configs).

    reload_factor / act_spill model the SRAM-CiM baseline's scheduling
    (paper Fig. 13b): when the activation working set exceeds the on-chip
    cache of the iso-area chip (YOLO at 416x416), the layer is processed
    in spatial tiles and weights stream from DRAM once per tile
    (reload_factor ~ 4) and activations spill to DRAM (act_spill).  Nets
    whose working set fits (Tiny-YOLO) reload weights exactly once.
    ``baseline``='all_sram' marks nets the paper compares against their
    full all-SRAM-CiM implementation (classification nets, Fig. 10).
    """
    name: str
    params: int                  # weight count
    macs: int                    # MACs per inference
    act_bits_moved: int          # inter-layer activation bits per inference
    branch_fraction: float = 1.0 / 16.0   # ReBranch D*U=16 default
    reload_factor: float = 1.0   # weight DRAM streams per inference
    act_spill: bool = False      # baseline spills activations to DRAM
    baseline: str = "iso_area"   # 'iso_area' | 'all_sram'


# ---------------------------------------------------------------------------
# areas (mm^2)
# ---------------------------------------------------------------------------

def yoloc_area(net: NetStats, cm: CostModel = DEFAULT_COST) -> float:
    trunk_bits = net.params * cm.weight_bits
    branch_bits = trunk_bits * net.branch_fraction
    return (trunk_bits / 1e6 / cm.rom_density_mb_mm2
            + branch_bits / 1e6 / cm.sram_density_mb_mm2)


def all_sram_area(net: NetStats, cm: CostModel = DEFAULT_COST) -> float:
    return net.params * cm.weight_bits / 1e6 / cm.sram_density_mb_mm2


# ---------------------------------------------------------------------------
# energies (mJ / inference)
# ---------------------------------------------------------------------------

def yoloc_energy(net: NetStats, cm: CostModel = DEFAULT_COST) -> dict:
    """(a) trunk on ROM-CiM, branch on SRAM-CiM, zero DRAM weight traffic."""
    branch_macs = net.macs * net.branch_fraction
    e_mac = (net.macs * cm.rom_pj_per_mac + branch_macs * cm.sram_pj_per_mac)
    e_cache = net.act_bits_moved * cm.sram_cache_pj_per_bit
    return {"mac": e_mac * 1e-9, "dram": 0.0, "link": 0.0,
            "cache": e_cache * 1e-9,
            "total": (e_mac + e_cache) * 1e-9}


def sram_single_energy(net: NetStats, cm: CostModel = DEFAULT_COST) -> dict:
    """(b) the SRAM-CiM comparison chip (paper Fig. 13b).

    'iso_area': chip area = YOLoC's; overflow weights stream from DRAM
    ``reload_factor`` times per inference (spatial tiling when the
    activation working set exceeds the cache), activations optionally
    spill.  'all_sram': the full SRAM-CiM implementation (no DRAM) — the
    paper's baseline for the classification nets.
    """
    w_bits = net.params * cm.weight_bits
    if net.baseline == "all_sram":
        reload_bits = 0.0
    else:
        area = yoloc_area(net, cm)                   # iso-area comparison
        capacity_bits = area * cm.sram_density_mb_mm2 * 1e6
        reload_bits = max(0.0, w_bits - capacity_bits) * net.reload_factor
    e_mac = net.macs * cm.sram_pj_per_mac
    e_dram = reload_bits * cm.dram_pj_per_bit
    if net.act_spill:          # activations round-trip DRAM (write+read)
        e_dram += 2.0 * net.act_bits_moved * cm.dram_pj_per_bit
    e_cache = net.act_bits_moved * cm.sram_cache_pj_per_bit
    return {"mac": e_mac * 1e-9, "dram": e_dram * 1e-9, "link": 0.0,
            "cache": e_cache * 1e-9, "reload_bits": reload_bits,
            "total": (e_mac + e_dram + e_cache) * 1e-9}


def chiplet_energy(net: NetStats, cm: CostModel = DEFAULT_COST) -> dict:
    """(c) SRAM-CiM chiplets holding all weights; features cross the package."""
    w_bits = net.params * cm.weight_bits
    n_chips = max(1, math.ceil(w_bits / cm.chiplet_bits))
    # Features cross chip boundaries proportionally to how the layers are
    # split: each boundary forwards the activation working set once.
    link_bits = net.act_bits_moved * (n_chips - 1) / max(1, n_chips)
    # chiplets hold all weights resident -> macro-level efficiency
    e_mac = net.macs * 2.0 / cm.sram_macro_tops_w
    e_link = link_bits * cm.link_pj_per_bit
    e_cache = net.act_bits_moved * cm.sram_cache_pj_per_bit
    return {"mac": e_mac * 1e-9, "dram": 0.0, "link": e_link * 1e-9,
            "cache": e_cache * 1e-9, "n_chips": n_chips,
            "total": (e_mac + e_link + e_cache) * 1e-9}


# ---------------------------------------------------------------------------
# latency (ms / inference)
# ---------------------------------------------------------------------------

def yoloc_latency(net: NetStats, cm: CostModel = DEFAULT_COST) -> dict:
    """Trunk and branch run in parallel macro pools (Fig. 9); the branch adds
    a small serialisation overhead (paper: +8% on YOLO)."""
    trunk_bits = net.params * cm.weight_bits
    n_macros = max(1, math.ceil(trunk_bits / cm.macro_bits))
    chip_gops = n_macros * cm.macro_gops
    t_trunk = 2.0 * net.macs / (chip_gops * 1e9) * 1e3          # ms
    # Branch macros scale with branch size; point-wise (de)compression is
    # extra serial work on the feature map.
    branch_macs = net.macs * net.branch_fraction
    n_bmacros = max(1, math.ceil(trunk_bits * net.branch_fraction / cm.macro_bits))
    t_branch = 2.0 * branch_macs / (n_bmacros * cm.macro_gops * 1e9) * 1e3
    t_merge = 0.08 * t_trunk         # add/requant pipeline bubbles (paper: 8%)
    total = max(t_trunk, t_branch) + t_merge
    return {"trunk": t_trunk, "branch": t_branch,
            "overhead_frac": total / t_trunk - 1.0, "total": total}


def sram_single_latency(net: NetStats, cm: CostModel = DEFAULT_COST) -> dict:
    area = yoloc_area(net, cm)
    capacity_bits = area * cm.sram_density_mb_mm2 * 1e6
    n_macros = max(1, math.ceil(capacity_bits / cm.macro_bits))
    t_mac = 2.0 * net.macs / (n_macros * cm.macro_gops * 1e9) * 1e3
    reload_bits = max(0.0, net.params * cm.weight_bits - capacity_bits)
    t_dram = reload_bits / 8 / (cm.dram_gbps * 1e9) * 1e3
    return {"mac": t_mac, "dram": t_dram, "total": t_mac + t_dram}


def efficiency_ratio(net: NetStats, cm: CostModel = DEFAULT_COST) -> float:
    """Energy-efficiency improvement of YOLoC over iso-area SRAM-CiM."""
    return sram_single_energy(net, cm)["total"] / yoloc_energy(net, cm)["total"]


def area_ratio(net: NetStats, cm: CostModel = DEFAULT_COST) -> float:
    """Chip-area saving of YOLoC over all-SRAM-CiM (Fig. 12)."""
    return all_sram_area(net, cm) / yoloc_area(net, cm)
