"""Architecture registry: the 10 assigned archs + the paper's own models.

Each module defines FULL (the published config, exercised only via the
dry-run) and SMOKE (a reduced same-family config that runs a real
forward/train step on CPU).  ``get(name)`` / ``get_smoke(name)`` look
them up; ``ALL_ARCHS`` lists the assigned ten.
"""

from __future__ import annotations

import importlib

ALL_ARCHS = [
    "musicgen_large", "qwen2_vl_2b", "yi_34b", "qwen15_32b", "gemma_2b",
    "deepseek_67b", "granite_moe_3b", "qwen2_moe_a2_7b", "hymba_1_5b",
    "falcon_mamba_7b",
]

# shape cells (assigned): name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get(name: str):
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.FULL


def get_smoke(name: str):
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.SMOKE


def cells(arch_name: str):
    """The (arch x shape) cells this arch executes; long_500k only for
    sub-quadratic families (skips documented in DESIGN.md)."""
    cfg = get(arch_name)
    out = []
    for shape, (seq, gb, kind) in SHAPES.items():
        if shape == "long_500k" and not cfg.supports_long_context:
            continue
        out.append((shape, seq, gb, kind))
    return out
