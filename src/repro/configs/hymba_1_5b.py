"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads, SWA in most
layers with 3 global-attention layers [arXiv:2411.13676; hf].
Meta tokens elided (see DESIGN.md)."""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="hymba_1_5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001, ssm_state=16,
    sliding_window=2048, full_attn_layers=(0, 15, 31),
)

SMOKE = ArchConfig(
    name="hymba_1_5b_smoke", family="hybrid",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=128, ssm_state=8,
    sliding_window=8, full_attn_layers=(0, 2), dtype="float32",
)
