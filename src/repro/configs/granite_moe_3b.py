"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8)
expert d_ff=512 vocab=49155, MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-*-base family; hf]."""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="granite_moe_3b", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, moe_d_ff=512, vocab_size=49155,
    num_experts=40, num_experts_per_tok=8,
    moe_group_size=256, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="granite_moe_3b_smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=64, moe_d_ff=64, vocab_size=128,
    num_experts=8, num_experts_per_tok=2, moe_group_size=32,
    tie_embeddings=True, dtype="float32",
)
