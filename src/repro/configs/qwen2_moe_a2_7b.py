"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="qwen2_moe_a2_7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, moe_d_ff=1408, vocab_size=151936,
    num_experts=60, num_experts_per_tok=4, num_shared_experts=4,
    moe_group_size=256, qkv_bias=True,
)

SMOKE = ArchConfig(
    name="qwen2_moe_a2_7b_smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=64, moe_d_ff=64, vocab_size=128,
    num_experts=6, num_experts_per_tok=2, num_shared_experts=2,
    moe_group_size=32, qkv_bias=True, dtype="float32",
)
