"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba-1 arch with dt/B/C RMSNorm [arXiv:2410.05355]."""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="falcon_mamba_7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024, ssm_state=16, expand=2, d_conv=4,
    ssm_norm=True,
)

SMOKE = ArchConfig(
    name="falcon_mamba_7b_smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=128, ssm_state=8, expand=2, d_conv=4,
    ssm_norm=True, dtype="float32",
)
