"""The paper's own evaluation models (§4.1): VGG-8, ResNet-18 on
CIFAR-scale inputs; YOLO (DarkNet-19) and Tiny-YOLO on 416x416 VOC."""

from repro.models.cnn import CNNConfig

VGG8 = CNNConfig(name="vgg8", num_classes=100, input_size=32)
RESNET18 = CNNConfig(name="resnet18", num_classes=100, input_size=32)
DARKNET19_YOLO = CNNConfig(name="darknet19", input_size=416,
                           head_anchors=5, head_classes=20)
TINY_YOLO = CNNConfig(name="tiny_yolo", input_size=416,
                      head_anchors=5, head_classes=20)

PAPER_MODELS = {
    "vgg8": VGG8,
    "resnet18": RESNET18,
    "darknet19": DARKNET19_YOLO,
    "tiny_yolo": TINY_YOLO,
}
