"""musicgen-large [audio]: decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048, 4 codebooks
[arXiv:2306.05284; hf].  The EnCodec frontend is a stub — input_specs
provides token ids per codebook (backbone-only per the assignment).
musicgen uses learned-position GELU-MLP transformers; we keep the
published dims and use the zoo's RoPE/SwiGLU-free path (mlp_type=gelu).
"""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="musicgen_large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, num_codebooks=4,
    mlp_type="gelu", rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="musicgen_large_smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=64, num_codebooks=4, mlp_type="gelu",
    dtype="float32",
)
