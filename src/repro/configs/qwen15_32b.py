"""qwen1.5-32b [dense]: 64L d_model=5120 40H (GQA kv=40... per the
assignment: kv=40 i.e. MHA-style KV) d_ff=27392 vocab=152064 — QKV bias
[hf:Qwen/Qwen1.5-0.5B family; hf]."""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="qwen15_32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064, qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen15_32b_smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=128, qkv_bias=True, dtype="float32",
)
