"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].
Vision frontend is a stub (precomputed patch embeddings)."""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="qwen2_vl_2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, mrope=True, qkv_bias=True,
    rope_theta=1_000_000.0, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="qwen2_vl_2b_smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=128, mrope=True, qkv_bias=True,
    tie_embeddings=True, dtype="float32",
)
