"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — llama-arch GQA [arXiv:2403.04652; hf]."""

from repro.models.config import ArchConfig

FULL = ArchConfig(
    name="yi_34b", family="dense",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000, rope_theta=5_000_000.0,
)

SMOKE = ArchConfig(
    name="yi_34b_smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=192, vocab_size=128, dtype="float32",
)
