"""Production mesh construction (function, not module-level constant, so
importing this module never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices this process actually has, on the data axis."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_cnn_serve_mesh(n_data: int = 8):
    """CNN serving mesh for the halo-exchange sharded conv engine:
    spatial H shards over ``data`` (rule ``"cnn_h"``), channels could
    ride ``model`` (kept 1 — trunk weights live whole in ROM macros).
    Uses the first ``n_data`` devices so it composes with the dry-run's
    512 forced host devices."""
    return jax.make_mesh((n_data, 1), ("data", "model"),
                         devices=jax.devices()[:n_data])
