"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch gemma_2b --steps 200 \
      --smoke --batch 8 --seq 64 --ckpt-dir /tmp/ck [--resume] [--compress]

Runs branch-only ReBranch training (frozen int8 ROM trunk) with:
  * deterministic resumable data (data/synthetic.py),
  * AdamW on the SRAM tree + cosine schedule + grad clip,
  * atomic keep-k checkpoints every --ckpt-every steps (+ SIGTERM trap
    for preemption: final checkpoint before exit),
  * optional int8 error-feedback gradient compression (--compress,
    shard_map over the data axis),
  * mesh: whatever devices exist (data axis), or the production mesh
    under the dry-run device flag.
"""

from __future__ import annotations

import argparse
import signal
import sys
import time

import jax

from repro import configs, optim
from repro.checkpoint import manager as ckpt
from repro.core import rebranch
from repro.data import synthetic
from repro.distributed import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.optim import schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient all-reduce")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    mesh = make_local_mesh()
    dcfg = synthetic.DataConfig(
        seed=args.seed, vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, num_codebooks=cfg.num_codebooks)

    from repro import deploy
    model = deploy.compile_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    with shd.use_mesh(mesh), mesh:
        params = model.init(key)
        trainable, frozen = rebranch.partition(params)
        opt_state = optim.init(trainable)
        lr_fn = lambda step: schedule.cosine_with_warmup(
            step, peak_lr=args.lr, warmup_steps=args.warmup,
            total_steps=args.steps)
        opt_cfg = optim.AdamWConfig(lr=args.lr)
        train_step = jax.jit(steps_lib.make_train_step(
            cfg, opt_cfg, lr_fn=lr_fn, loss_chunks=4, model=model))

        start = 0
        if args.resume and args.ckpt_dir and ckpt.latest_steps(args.ckpt_dir):
            start, trainable, opt_state, _ = ckpt.restore(
                args.ckpt_dir, trainable, opt_state, params)
            print(f"[train] resumed from step {start}", flush=True)

        # preemption: checkpoint on SIGTERM, then exit cleanly
        state = {"step": start, "trainable": trainable, "opt": opt_state}

        def _on_sigterm(signum, frame):
            if args.ckpt_dir:
                ckpt.save(args.ckpt_dir, state["step"], state["trainable"],
                          state["opt"], params)
                print(f"[train] SIGTERM: checkpointed step {state['step']}",
                      flush=True)
            sys.exit(0)

        signal.signal(signal.SIGTERM, _on_sigterm)

        n_sram = rebranch.trainable_count(params)
        n_rom = rebranch.frozen_count(params)
        print(f"[train] {cfg.name}: ROM {n_rom/1e6:.2f}M params (frozen), "
              f"SRAM {n_sram/1e6:.2f}M trainable "
              f"({n_rom/(n_rom+n_sram):.1%} in ROM)", flush=True)
        if args.compress:
            print("[train] int8 error-feedback gradient compression ON",
                  flush=True)

        losses = []
        t0 = time.time()
        io_thread = None
        for step in range(start, args.steps):
            batch = synthetic.markov_batch(dcfg, step)
            trainable, opt_state, metrics = train_step(
                trainable, frozen, opt_state, batch)
            state.update(step=step + 1, trainable=trainable, opt=opt_state)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                dt = (time.time() - t0) / args.log_every
                print(f"[train] step {step+1:5d} "
                      f"loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"({dt*1e3:.0f} ms/step)", flush=True)
                t0 = time.time()
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                if io_thread is not None:
                    io_thread.join()
                io_thread = ckpt.save(args.ckpt_dir, step + 1, trainable,
                                      opt_state, params, async_=True)
        if io_thread is not None:
            io_thread.join()
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, args.steps, trainable, opt_state, params)

        floor = synthetic.entropy_floor(dcfg)
        print(f"[train] done. loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"(entropy floor {floor:.4f})", flush=True)
        return losses


if __name__ == "__main__":
    main()
