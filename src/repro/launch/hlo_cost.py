"""HLO cost parser: FLOPs / HBM bytes / collective bytes with correct
while-loop (lax.scan) trip-count multipliers.

XLA's built-in cost_analysis() counts a while body ONCE regardless of
trip count, which silently undercounts every scan-over-layers model by
~L and every chunked-attention scan by S/chunk.  This parser walks the
partitioned HLO text, resolves operand shapes per computation, multiplies
nested computation costs by the loop trip count (extracted from the loop
condition's comparison constant), and sums:

  * flops            : dot (2*M*N*K incl. int8) + convolution
  * hbm_bytes        : sum over top-level instructions of operand+output
                       bytes (fusion-granular — XLA-TPU-style traffic est.)
  * collective_bytes : all-gather/all-reduce/reduce-scatter/all-to-all/
                       collective-permute output bytes

All numbers are per-device (the partitioned module's shapes are local).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\(?[^(]*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s+\(.*\)\s*->")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(s: str):
    """All (dtype, dims) found in a type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _nbytes(s: str) -> int:
    return sum(_DTYPE_BYTES[dt] * _prod(shape)
               for dt, shape in _parse_shapes(s))


def _prod(t):
    n = 1
    for x in t:
        n *= x
    return n


class Instr:
    __slots__ = ("name", "otype", "op", "rest")

    def __init__(self, name, otype, op, rest):
        self.name, self.otype, self.op, self.rest = name, otype, op, rest


def parse_module(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and "{" in line:
            cur = mc.group(1).lstrip("%")
            comps[cur] = []
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            comps[cur].append(
                Instr(mi.group(1).lstrip("%"), mi.group(2), mi.group(3),
                      mi.group(4)))
        if line.strip() == "}":
            cur = None
    return comps


def _operand_names(rest: str) -> list[str]:
    # operands are %names before the first '),' or metadata
    args = rest.split("),")[0]
    return re.findall(r"%([\w.\-]+)", args)


def _trip_count(while_rest: str, cond_instrs: list[Instr]) -> int:
    """Loop bound: XLA's known_trip_count backend_config, else the largest
    s32 constant in the condition computation (the loop bound)."""
    m = re.search(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"', while_rest)
    if m:
        return int(m.group(1))
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant" and "s32" in ins.otype:
            mc = re.search(r"constant\((\d+)\)", ins.name + " = x " +
                           "constant(" + ins.rest)
            mc = re.match(r"(\d+)\)", ins.rest)
            if mc:
                best = max(best, int(mc.group(1)))
    return best


def _dot_flops(ins: Instr, shapes: dict[str, str]) -> float:
    out_elems = sum(_prod(s) for _, s in _parse_shapes(ins.otype))
    ops = _operand_names(ins.rest)
    if not ops:
        return 0.0
    lhs_type = shapes.get(ops[0], "")
    lhs_shapes = _parse_shapes(lhs_type)
    if not lhs_shapes:
        return 0.0
    lhs = lhs_shapes[0][1]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    k = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs):
                k *= lhs[di]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, shapes: dict[str, str]) -> float:
    out_elems = sum(_prod(s) for _, s in _parse_shapes(ins.otype))
    ops = _operand_names(ins.rest)
    if len(ops) < 2:
        return 0.0
    ker = _parse_shapes(shapes.get(ops[1], ""))
    if not ker:
        return 0.0
    kshape = ker[0][1]
    # HWIO kernel: flops per output elem = 2 * prod(kernel) / O
    o = kshape[-1] if kshape else 1
    return 2.0 * out_elems * _prod(kshape) / max(o, 1)


def analyse_text(text: str) -> dict:
    comps = parse_module(text)
    entry = None
    for name in comps:
        if "main" in name or entry is None:
            pass
    # ENTRY computation: the one whose name appears after 'ENTRY' keyword
    m = re.search(r"ENTRY\s+(%?[\w.\-]+)", text)
    entry = m.group(1).lstrip("%") if m else next(iter(comps))

    memo: dict[str, dict] = {}

    def comp_cost(cname: str) -> dict:
        if cname in memo:
            return memo[cname]
        memo[cname] = {"flops": 0.0, "hbm": 0.0, "coll": 0.0,
                       "coll_by_op": defaultdict(float)}
        cost = {"flops": 0.0, "hbm": 0.0, "coll": 0.0,
                "coll_by_op": defaultdict(float)}
        instrs = comps.get(cname, [])
        shapes = {i.name: i.otype for i in instrs}
        for ins in instrs:
            op = ins.op
            base = op.replace("-start", "") if op.endswith("-start") else op
            if op == "dot":
                cost["flops"] += _dot_flops(ins, shapes)
            elif op == "convolution":
                cost["flops"] += _conv_flops(ins, shapes)
            elif base in _COLLECTIVES:
                b = _nbytes(ins.otype)
                cost["coll"] += b
                cost["coll_by_op"][base] += b
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cond = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                if body and cond:
                    trips = _trip_count(ins.rest,
                                        comps.get(cond.group(1), []))
                    sub = comp_cost(body.group(1))
                    for k2 in ("flops", "hbm", "coll"):
                        cost[k2] += trips * sub[k2]
                    for k2, v in sub["coll_by_op"].items():
                        cost["coll_by_op"][k2] += trips * v
            elif op in ("fusion", "call", "custom-call", "conditional",
                        "reduce", "sort", "scatter", "map"):
                for sub_m in re.finditer(
                        r"(?:calls|to_apply|branch_computations=\{|"
                        r"fusion_computation)=?%?([\w.\-]+)", ins.rest):
                    sub = comp_cost(sub_m.group(1))
                    for k2 in ("flops", "coll"):
                        cost[k2] += sub[k2]
                    for k2, v in sub["coll_by_op"].items():
                        cost["coll_by_op"][k2] += v
            # HBM traffic: top-level instruction operand+output bytes.
            # Alias-aware: when an operand has the same type as the output
            # (dynamic-update-slice fusions on loop state, elementwise
            # accumulations), XLA updates the buffer in place — count the
            # other operands only, not a full read+write of the big buffer
            # (otherwise a scanned KV-cache update is billed as a full
            # cache copy per layer per step: ~1000x overcount).
            if op not in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "while"):
                out_b = _nbytes(ins.otype)
                operand_bytes = [_nbytes(shapes.get(o, ""))
                                 for o in _operand_names(ins.rest)]
                aliased = False
                for i, o in enumerate(_operand_names(ins.rest)):
                    if shapes.get(o, "") == ins.otype and out_b > 0:
                        aliased = True
                        operand_bytes[i] = 0
                        break
                b = sum(operand_bytes) + (0 if aliased else out_b)
                cost["hbm"] += b
        memo[cname] = cost
        return cost

    total = comp_cost(entry)
    return {
        "flops": total["flops"],
        "hbm_bytes": total["hbm"],
        "collective_bytes": total["coll"],
        "collectives": {k: v for k, v in total["coll_by_op"].items()},
    }
