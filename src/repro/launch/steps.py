"""Step builders + input specs for training and serving.

These are the functions the dry-run lowers for every (arch x shape x mesh)
cell and the train driver executes for real:

  train_step   : fwd + bwd (branch-only grads) + AdamW + metrics
  prefill_step : full-sequence forward writing a fresh KV/SSM cache
  serve_step   : one decode token against a seq_len-sized cache
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import deploy, optim
from repro.core import rebranch
from repro.distributed import sharding as shd
from repro.models.config import ArchConfig


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, seq_len: int, global_batch: int,
                kind: str) -> dict:
    """Stand-ins for every model input of a step of the given kind."""
    i32 = jnp.int32
    tok_shape = ((global_batch, seq_len, cfg.num_codebooks)
                 if cfg.num_codebooks else (global_batch, seq_len))
    if kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct(tok_shape, i32),
            "labels": jax.ShapeDtypeStruct(tok_shape, i32),
        }
        if cfg.family == "vlm":
            specs["embeds"] = jax.ShapeDtypeStruct(
                (global_batch, seq_len, cfg.d_model), jnp.bfloat16)
        return specs
    if kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct(tok_shape, i32)}
        if cfg.family == "vlm":
            specs["embeds"] = jax.ShapeDtypeStruct(
                (global_batch, seq_len, cfg.d_model), jnp.bfloat16)
        return specs
    if kind == "decode":
        one = ((global_batch, 1, cfg.num_codebooks)
               if cfg.num_codebooks else (global_batch, 1))
        return {"tokens": jax.ShapeDtypeStruct(one, i32)}
    raise ValueError(kind)


def batch_pspec(cfg: ArchConfig, mesh, global_batch: int):
    """PartitionSpec for token-like inputs (batch over pod+data, or
    replicated for batch-1 long-context cells)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if global_batch >= total:
        return tuple(axes) if len(axes) > 1 else axes[0]
    return None


def batch_shardings(cfg: ArchConfig, mesh, specs: dict, global_batch: int):
    b = batch_pspec(cfg, mesh, global_batch)

    def one(name, s):
        if s.ndim >= 2:
            return NamedSharding(mesh, P(b, *([None] * (s.ndim - 1))))
        return NamedSharding(mesh, P())
    return {k: one(k, v) for k, v in specs.items()}


# ---------------------------------------------------------------------------
# cache specs + shardings
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, global_batch: int, max_len: int):
    model = deploy.compile_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(global_batch, max_len))


def cache_pspecs(cfg: ArchConfig, mesh, cache_tree):
    """Path+shape-aware PartitionSpecs for KV/SSM caches."""
    baxes = [a for a in ("pod", "data") if a in mesh.axis_names]
    b_total = int(np.prod([mesh.shape[a] for a in baxes]))
    m_size = mesh.shape.get("model", 1)
    all_axes = tuple(mesh.axis_names)

    import re
    layer_list = re.compile(r"\['layers'\]\[\d+\]")

    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        # scan-over-layers archs stack caches with a leading L dim
        stacked = "['layers']" in p and not layer_list.search(p)
        shape = leaf.shape[1:] if stacked else leaf.shape
        nd = len(shape)
        pre = (None,) if stacked else ()
        if ("'k'" in p or "'v'" in p) and nd == 4:
            bsz, s, kv, _ = shape
            bspec = tuple(baxes) if bsz >= b_total else None
            if bspec is None:
                # batch-1 long-context: shard the sequence instead
                return P(*pre, None,
                         tuple(all_axes) if s % mesh.size == 0 else None,
                         None, None)
            if kv % m_size == 0:
                return P(*pre, bspec, None, "model", None)
            if s % m_size == 0:
                # flash-decoding style: kv heads don't divide the model
                # axis (deepseek kv=8, gemma kv=1) -> shard the cache
                # sequence; softmax stats psum over the model axis
                return P(*pre, bspec, "model", None, None)
            return P(*pre, bspec, None, None, None)
        if "'h'" in p and nd == 3:                 # [B, d_inner, N]
            bsz = shape[0]
            bspec = tuple(baxes) if bsz >= b_total else None
            return P(*pre, bspec,
                     "model" if shape[1] % m_size == 0 else None, None)
        if "'conv'" in p and nd == 3:              # [B, K-1, d_inner]
            bsz = shape[0]
            bspec = tuple(baxes) if bsz >= b_total else None
            return P(*pre, bspec, None,
                     "model" if shape[2] % m_size == 0 else None)
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def cache_shardings(cfg: ArchConfig, mesh, cache_tree):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        cache_pspecs(cfg, mesh, cache_tree),
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def token_cross_entropy(logits, labels):
    """CE over the last axis; supports [B,S,V] and [B,S,Q,V].

    Uses logsumexp + a one-hot einsum rather than take_along_axis: gather
    over the vocab-sharded axis would force GSPMD to all-gather the full
    logits (67 GiB/device for gemma train_4k); the one-hot contraction
    keeps everything local + one scalar psum."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.bfloat16)
    picked = jnp.einsum("...v,...v->...", lf,
                        onehot.astype(jnp.float32))
    return jnp.mean(lse - picked)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def chunked_readout_loss(params, feats, labels, cfg: ArchConfig,
                         num_chunks: int = 8, model=None):
    """ln_f + readout + CE in sequence chunks via a checkpointed scan.

    The full-vocab logits tensor never materialises for more than one
    chunk (gemma train_4k: 0.5 GiB/chunk instead of ~4 GiB x 5 buffers);
    the backward recomputes each chunk's logits.
    """
    model = model or deploy.compile_model(cfg)
    b, s, d = feats.shape
    nc = num_chunks
    while s % nc:
        nc -= 1
    fc = jnp.moveaxis(feats.reshape(b, nc, s // nc, d), 1, 0)
    lshape = labels.shape[2:]          # () or (Q,)
    lc = jnp.moveaxis(labels.reshape(b, nc, s // nc, *lshape), 1, 0)

    def chunk_fn(carry, inp):
        xc, yc = inp
        logits = model.apply_head(params, xc)
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        onehot = jax.nn.one_hot(yc, logits.shape[-1], dtype=jnp.bfloat16)
        picked = jnp.einsum("...v,...v->...", lf, onehot.astype(jnp.float32))
        return carry + jnp.sum(lse - picked), None

    total, _ = jax.lax.scan(jax.checkpoint(chunk_fn),
                            jnp.zeros((), jnp.float32), (fc, lc))
    return total / labels.size


def make_train_step(cfg: ArchConfig, opt_cfg: optim.AdamWConfig | None = None,
                    lr_fn=None, loss_chunks: int = 8, model=None):
    opt_cfg = opt_cfg or optim.AdamWConfig()
    model = model or deploy.compile_model(cfg)

    def train_step(trainable, frozen, opt_state, batch):
        def loss_fn(t):
            params = rebranch.combine(t, frozen)
            feats = model.features(params, batch)
            return chunked_readout_loss(params, feats, batch["labels"],
                                        cfg, loss_chunks, model=model)

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        lr = lr_fn(opt_state["step"]) if lr_fn else opt_cfg.lr
        new_t, new_opt, m = optim.update(grads, opt_state, trainable,
                                         opt_cfg, lr=lr)
        metrics = {"loss": loss, "grad_norm": m["grad_norm"],
                   "lr": jnp.asarray(lr, jnp.float32)}
        return new_t, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, global_batch: int, seq_len: int,
                      model=None):
    model = model or deploy.compile_model(cfg)

    def prefill_step(params, batch):
        cache = model.init_cache(global_batch, seq_len)
        return model.prefill(params, batch, cache)
    return prefill_step


def make_serve_step(cfg: ArchConfig, model=None):
    model = model or deploy.compile_model(cfg)

    def serve_step(params, batch, cache):
        logits, cache = model.decode_step(params, batch["tokens"], cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache
    return serve_step


# ---------------------------------------------------------------------------
# parameter/optimizer shardings
# ---------------------------------------------------------------------------

def model_state_shardings(cfg: ArchConfig, mesh, key=None, model=None):
    """(trainable, frozen, opt) shardings without allocating parameters."""
    key = key if key is not None else jax.random.PRNGKey(0)
    model = model or deploy.compile_model(cfg)
    shapes = jax.eval_shape(model.init, key)
    with shd.use_mesh(mesh):
        pspecs = shd.param_specs(shapes, mesh)
    t_spec, f_spec = rebranch.partition(pspecs)
    t_shapes, _ = rebranch.partition(shapes)
    as_shard = lambda tree: jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s),
        tree, is_leaf=lambda s: s is None or isinstance(s, P))
    t_sh, f_sh = as_shard(t_spec), as_shard(f_spec)
    opt_shapes = jax.eval_shape(optim.init, t_shapes)
    opt_sh = {
        "step": NamedSharding(mesh, P()),
        "m": jax.tree.map(lambda s: s, t_sh,
                          is_leaf=lambda s: s is None or isinstance(
                              s, NamedSharding)),
        "v": jax.tree.map(lambda s: s, t_sh,
                          is_leaf=lambda s: s is None or isinstance(
                              s, NamedSharding)),
    }
    del opt_shapes
    return t_sh, f_sh, opt_sh, shapes
