import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step for train_4k,
prefill_step for prefill_32k, serve_step for decode shapes) against
ShapeDtypeStruct inputs on the production mesh, compiles it, and records:

  * memory_analysis  (bytes per device — proves it fits)
  * cost_analysis    (HLO FLOPs / bytes — roofline compute & memory terms)
  * collective bytes (parsed from the compiled HLO — roofline comm term)

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun [--arch yi_34b]
      [--shape train_4k] [--multi-pod] [--single-pod] [--out out.json]

Beyond the LM cells, ``--shape cnn_serve`` (also part of the full sweep)
lowers the H-sharded CNN inference cells (DarkNet-19 / ResNet-18 on the
'pallas_sharded' halo-exchange engine, see CNN_SERVE) on a small
data-axis mesh — the halo traffic lands in the collective-permute bytes.

``--shape fig12`` walks ROM/SRAM area budgets for DarkNet-19 /
ResNet-18 / Tiny-YOLO through the cost-driven placement solver
(``repro.plan.solve``) and emits the per-layer area map + energy ratios
— the paper's Fig. 12 tradeoff reproduced end to end from the site
trees.  ``--fast`` trims the budget sweep for the CI smoke step.
"""

import argparse
import gzip
import json
import os as _os
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import configs, optim
from repro.core import rebranch
from repro.distributed import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_cnn_serve_mesh, make_production_mesh


# ---------------------------------------------------------------------------
# HLO collective parsing (roofline comm term)
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _op_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand sizes of every collective op in the HLO."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?[%\w.\-]+ = \(?([^)]*?)\)? (\S+)\(", s)
        if not m:
            continue
        opname = m.group(2).split(".")[0]
        for c in _COLLECTIVES:
            if opname == c or opname == c + "-start":
                out[c] += _op_bytes(m.group(1))
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# per-cell dry-run
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, mesh, *, donate: bool = True):
    """Lower + compile one cell; returns the result record."""
    cfg = configs.get(arch)
    seq, gbatch, kind = dict(
        (s, (q, b, k)) for s, q, b, k in configs.cells(arch))[shape_name]

    t0 = time.time()
    with shd.use_mesh(mesh), mesh:
        t_sh, f_sh, opt_sh, param_shapes = steps_lib.model_state_shardings(
            cfg, mesh)
        in_specs = steps_lib.input_specs(cfg, seq, gbatch, kind)
        in_sh = steps_lib.batch_shardings(cfg, mesh, in_specs, gbatch)
        t_shapes, f_shapes = rebranch.partition(param_shapes)

        if kind == "train":
            step = steps_lib.make_train_step(cfg)
            opt_shapes = jax.eval_shape(optim.init, t_shapes)
            jitted = jax.jit(
                step,
                in_shardings=(t_sh, f_sh, opt_sh, in_sh),
                donate_argnums=(0, 2) if donate else (),
            )
            lowered = jitted.lower(t_shapes, f_shapes, opt_shapes, in_specs)
        elif kind == "prefill":
            step = steps_lib.make_prefill_step(cfg, gbatch, seq)
            jitted = jax.jit(step, in_shardings=(
                rebranch.combine(t_sh, f_sh), in_sh))
            lowered = jitted.lower(param_shapes, in_specs)
        else:  # decode
            step = steps_lib.make_serve_step(cfg)
            c_shapes = steps_lib.cache_specs(cfg, gbatch, seq)
            c_sh = steps_lib.cache_shardings(cfg, mesh, c_shapes)
            jitted = jax.jit(
                step,
                in_shardings=(rebranch.combine(t_sh, f_sh), in_sh, c_sh),
                donate_argnums=(2,) if donate else (),
            )
            lowered = jitted.lower(param_shapes, in_specs, c_shapes)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        txt = compiled.as_text()        # rendered once; multi-hundred-MB
        rec = analyse_compiled(compiled, mesh, hlo_text=txt)
        hlo_dir = _os.environ.get("DRYRUN_HLO_DIR")
        if hlo_dir:
            _os.makedirs(hlo_dir, exist_ok=True)
            mesh_tag = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
            with gzip.open(_os.path.join(
                    hlo_dir, f"{arch}_{shape_name}_{mesh_tag}.hlo.gz"),
                    "wt") as f:
                f.write(txt)

    rec.update(
        arch=arch, shape=shape_name, kind=kind,
        seq=seq, global_batch=gbatch,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1))
    return rec


def analyse_compiled(compiled, mesh, hlo_text: str | None = None) -> dict:
    """The shared analysis fields of one compiled cell (LM or CNN):
    memory analysis, HLO cost (incl. while-loop trip counts — XLA's own
    cost_analysis counts scan bodies once, see hlo_cost.py), and the
    collective-byte breakdown parsed from the partitioned HLO.  Pass
    ``hlo_text`` if the caller already rendered ``compiled.as_text()``
    (it is hundreds of MB for multi-pod cells)."""
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):          # per-computation list form
        cost = cost[0] if cost else {}
    from repro.launch import hlo_cost
    costs = hlo_cost.analyse_text(hlo_text if hlo_text is not None
                                  else compiled.as_text())
    return {
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "devices": mesh.size,
        "flops": costs["flops"],
        "hbm_bytes": costs["hbm_bytes"],
        "xla_flops": float(cost.get("flops", -1)),
        "collective_bytes": costs["collective_bytes"],
        "collectives": costs["collectives"],
        "argument_bytes_per_dev": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes_per_dev": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes_per_dev": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes_per_dev": (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
    }


# ---------------------------------------------------------------------------
# cnn_serve cells: H-sharded CNN inference on the halo-exchange engine
# ---------------------------------------------------------------------------

# model -> (input_size, global_batch).  Sizes are serving-realistic for the
# halo math (several pool stages deep the per-device H hits the general /
# uneven path) while keeping interpret-mode Pallas compile times sane on
# the forced host devices.
CNN_SERVE = {
    "darknet19": (64, 8),
    "resnet18": (64, 8),
}
CNN_SERVE_DEVICES = 8


def lower_cnn_cell(name: str, mesh):
    """Lower + compile one H-sharded CNN forward on the 'pallas_sharded'
    engine; returns a record with the same analysis fields as LM cells
    (memory / HLO cost / collective bytes — the halo exchange shows up as
    collective-permute traffic)."""
    import dataclasses as _dc

    from repro import deploy
    from repro.core import cim as cim_lib
    from repro.models import cnn as cnn_lib

    size, gbatch = CNN_SERVE[name]
    spec = _dc.replace(rebranch.ReBranchSpec(),
                       trunk_impl="pallas_sharded",
                       cim=cim_lib.CiMConfig(mode="ideal"))
    cfg = cnn_lib.CNNConfig(name=name, input_size=size, rebranch=spec,
                            fuse_bn_act=True)
    model = deploy.compile_model(cfg, mesh=mesh)

    t0 = time.time()
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    x = jax.ShapeDtypeStruct((gbatch, size, size, 3), jnp.float32)
    with shd.use_mesh(mesh), mesh:
        in_sh = NamedSharding(mesh, shd.logical_to_spec(
            ("cnn_batch", "cnn_h"), mesh))
        jitted = jax.jit(model.forward, in_shardings=(None, in_sh))
        lowered = jitted.lower(param_shapes, x)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        rec = analyse_compiled(compiled, mesh)

    rec.update(
        arch=name, shape="cnn_serve", kind="cnn_serve",
        seq=size, global_batch=gbatch,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1))
    return rec


# ---------------------------------------------------------------------------
# fig12 cells: cost-driven ROM/SRAM placement sweeps (analytic, no compile)
# ---------------------------------------------------------------------------

# model -> iso-area baseline weight-reload factor (matches the Fig. 13b
# scheduling in benchmarks.netstats: DarkNet-19 at 416px tiles spatially
# and re-streams weights; the smaller nets reload once)
FIG12_MODELS = {"darknet19": 3.0, "resnet18": 1.0, "tiny_yolo": 1.0}


def run_fig12(name: str, fast: bool = False):
    """Budget sweep for one paper CNN: records of the solved placement at
    each area budget (area map + energy ratios), plus the per-site
    residency map at the all-ROM design point."""
    from repro import plan as plan_lib
    from repro.configs.paper_models import PAPER_MODELS

    cfg = PAPER_MODELS[name]
    reload_factor = FIG12_MODELS[name]
    records = []
    points = 3 if fast else 9
    for rec in plan_lib.sweep(cfg, points, reload_factor=reload_factor):
        plan = rec.pop("plan")
        stats = plan.stats(cfg)
        rec.update(
            arch=name, shape="fig12", kind="fig12",
            rom_mbit=round(stats.rom_bits / 1e6, 2),
            branch_mbit=round(stats.branch_bits / 1e6, 2),
            sram_mbit=round(stats.sram_bits / 1e6, 2),
            total_gmacs=round(stats.total_macs / 1e9, 3))
        records.append(rec)
    # the per-site area map at the design point (budget = all-ROM area):
    # which layer sits on which substrate, Fig. 12's x-axis
    design = plan_lib.solve(cfg)
    tree = plan_lib.site_tree(cfg)
    records[0]["area_map"] = [
        {"site": s.name, "residency": design.residency(s.name),
         "weights": s.total_weights, "gmacs": round(s.total_macs / 1e9, 3)}
        for s in tree]
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="only the 2x16x16 mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="only the 16x16 mesh")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="trim analytic sweeps (fig12) for CI smoke")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else configs.ALL_ARCHS
    cnn_archs = [a for a in archs if a in CNN_SERVE]
    lm_archs = [a for a in archs if a not in CNN_SERVE]
    meshes = []
    if not args.multi_pod:
        meshes.append(("single_pod", make_production_mesh(multi_pod=False)))
    if not args.single_pod:
        meshes.append(("multi_pod", make_production_mesh(multi_pod=True)))

    records, failures = [], []
    for arch in (lm_archs if args.shape not in ("cnn_serve", "fig12")
                 else []):
        for shape_name, *_ in configs.cells(arch):
            if args.shape and shape_name != args.shape:
                continue
            for mesh_name, mesh in meshes:
                tag = f"{arch} x {shape_name} x {mesh_name}"
                try:
                    rec = lower_cell(arch, shape_name, mesh,
                                     donate=not args.no_donate)
                    rec["mesh_name"] = mesh_name
                    records.append(rec)
                    print(f"[ok] {tag}: "
                          f"peak={rec['peak_bytes_per_dev']/2**30:.2f}GiB/dev "
                          f"flops={rec['flops']:.3g} "
                          f"coll={rec['collective_bytes']/2**20:.1f}MiB "
                          f"(lower {rec['lower_s']}s compile "
                          f"{rec['compile_s']}s)", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
                    traceback.print_exc()

    # cnn_serve family: included in full sweeps and via --shape cnn_serve /
    # --arch darknet19; runs on its own small H-sharding mesh, not the LM
    # production meshes (the trunk is fixed ROM — spatial, not tensor,
    # parallelism is the scaling axis)
    # fig12 cells honour --arch like cnn_serve does: an explicit arch
    # outside FIG12_MODELS simply runs no fig12 sweeps
    if args.shape in (None, "fig12"):
        fig12_archs = ([args.arch] if args.arch in FIG12_MODELS
                       else [] if args.arch else list(FIG12_MODELS))
        for name in fig12_archs:
            tag = f"{name} x fig12"
            try:
                recs = run_fig12(name, fast=args.fast)
                records.extend(recs)
                lo, hi = recs[0], recs[-1]
                n_sram = ", ".join(
                    f"{r['sram_sites']}/{r['rom_sites'] + r['sram_sites']}"
                    for r in recs)
                print(f"[ok] {tag}: area {lo['area_mm2']}->"
                      f"{hi['area_mm2']}mm2, eff {lo['efficiency_x']}x->"
                      f"{hi['efficiency_x']}x, sram sites [{n_sram}]",
                      flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e!r}", flush=True)
                traceback.print_exc()

    if args.shape in (None, "cnn_serve"):
        cnn_mesh = make_cnn_serve_mesh(CNN_SERVE_DEVICES)
        for name in (cnn_archs if args.arch else list(CNN_SERVE)):
            tag = f"{name} x cnn_serve x cnn_{CNN_SERVE_DEVICES}dev"
            try:
                rec = lower_cnn_cell(name, cnn_mesh)
                rec["mesh_name"] = f"cnn_{CNN_SERVE_DEVICES}dev"
                records.append(rec)
                print(f"[ok] {tag}: "
                      f"peak={rec['peak_bytes_per_dev']/2**30:.2f}GiB/dev "
                      f"flops={rec['flops']:.3g} "
                      f"coll={rec['collective_bytes']/2**20:.1f}MiB "
                      f"(lower {rec['lower_s']}s compile "
                      f"{rec['compile_s']}s)", flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e!r}", flush=True)
                traceback.print_exc()

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    print(f"\n{len(records)} cells ok, {len(failures)} failed")
    for tag, err in failures:
        print(f"  FAIL {tag}: {err[:200]}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
