"""Pure-jnp oracles for the Pallas kernels.

Each kernel in this package has a reference implementation here with
*identical* numerics (same quantisation granularity, same ADC model, same
blocking where it affects results).  Tests sweep shapes/dtypes and
assert_allclose kernel-vs-oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cim as cim_lib
from repro.core.quant import INT8_MAX


def cim_matmul_ref(x_q: jax.Array, w_q: jax.Array,
                   cfg: cim_lib.CiMConfig) -> jax.Array:
    """Oracle for kernels.cim_matmul: the core CiM macro model."""
    return cim_lib.cim_matmul_model(x_q, w_q, cfg)


def _block_quant(x: jax.Array, block_k: int):
    """Per-(row, k-block) dynamic int8 quantisation — matches the fused
    kernel's in-VMEM quantisation granularity exactly."""
    m, k = x.shape
    assert k % block_k == 0
    xb = x.reshape(m, k // block_k, block_k)
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / INT8_MAX
    x_q = jnp.clip(jnp.round(xb / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return x_q, scale


def rebranch_matmul_ref(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                        c: jax.Array, core: jax.Array, u: jax.Array,
                        block_k: int = 512) -> jax.Array:
    """Oracle for kernels.rebranch_matmul (fused trunk + branch).

      trunk = sum_kb (quant_kb(x) @ w_q[kb]) * scale_kb        (int8 path)
      out   = trunk * w_scale + ((x @ C) @ core) @ U
    """
    m, k = x.shape
    pad = (-k) % block_k
    if pad:
        xp = jnp.pad(x, ((0, 0), (0, pad)))
        wp = jnp.pad(w_q, ((0, pad), (0, 0)))
        cp = jnp.pad(c, ((0, pad), (0, 0)))
    else:
        xp, wp, cp = x, w_q, c
    x_q, scale = _block_quant(xp.astype(jnp.float32), block_k)
    wb = wp.reshape(-1, block_k, w_q.shape[1])
    acc = jnp.einsum(
        "msk,skn->msn",
        x_q.astype(jnp.float32) * scale,
        wb.astype(jnp.float32),
    ).sum(axis=1)
    trunk = acc * w_scale.reshape(1, -1).astype(jnp.float32)
    t1 = xp.astype(jnp.float32) @ cp.astype(jnp.float32)
    branch = (t1 @ core.astype(jnp.float32)) @ u.astype(jnp.float32)
    return (trunk + branch).astype(x.dtype)
