"""Pure-jnp oracles for the Pallas kernels.

Each kernel in this package has a reference implementation here with
*identical* numerics (same quantisation granularity, same ADC model, same
blocking where it affects results).  Tests sweep shapes/dtypes and
assert_allclose kernel-vs-oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cim as cim_lib
from repro.core.quant import quant_rows


def cim_matmul_ref(x_q: jax.Array, w_q: jax.Array,
                   cfg: cim_lib.CiMConfig) -> jax.Array:
    """Oracle for kernels.cim_matmul: the core CiM macro model."""
    return cim_lib.cim_matmul_model(x_q, w_q, cfg)


def rebranch_matmul_ref(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                        c: jax.Array, core: jax.Array, u: jax.Array,
                        cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(
                            mode="ideal"),
                        block_k: int = 512) -> jax.Array:
    """Oracle for kernels.rebranch_matmul (fused trunk + branch).

      trunk = sum_kb macro(quant_kb(x), w_q[kb]) * scale_kb   (CiM macro)
      out   = trunk * w_scale + ((x @ C) @ core) @ U

    The trunk goes block-by-block through the core macro model (all
    three fidelity modes), with the kernel's reciprocal-form per-(row,
    k-block) quantisation — see :func:`_blocked_cim_trunk`.
    """
    acc = _blocked_cim_trunk(x.astype(jnp.float32), w_q, cfg, block_k)
    trunk = acc * w_scale.reshape(1, -1).astype(jnp.float32)
    t1 = x.astype(jnp.float32) @ c.astype(jnp.float32)
    branch = (t1 @ core.astype(jnp.float32)) @ u.astype(jnp.float32)
    return (trunk + branch).astype(x.dtype)


# ---------------------------------------------------------------------------
# conv kernels (kernels/rebranch_conv.py)
# ---------------------------------------------------------------------------

def cim_conv_ref(x_q: jax.Array, w_q: jax.Array, cfg: cim_lib.CiMConfig,
                 stride: int = 1, padding: str = "SAME") -> jax.Array:
    """Oracle for kernels.cim_conv: im2col through the core CiM model."""
    return cim_lib.cim_conv_model(x_q, w_q, cfg, stride, padding)


def _blocked_cim_trunk(p: jax.Array, w_mat: jax.Array,
                       cfg: cim_lib.CiMConfig, block_k: int) -> jax.Array:
    """Patch matmul with the fused kernels' exact numerics: per-(row,
    k-block) dynamic quantisation, macro math per block, per-block scale.
    K blocks are subarray-aligned, so running the macro model block-by-block
    is identical to running it over the full contraction."""
    m, r = p.shape
    bk = min(block_k, -(-r // cfg.rows_per_subarray) * cfg.rows_per_subarray)
    pad = (-r) % bk
    pp = jnp.pad(p, ((0, 0), (0, pad)))
    wp = jnp.pad(w_mat, ((0, pad), (0, 0)))
    acc = jnp.zeros((m, w_mat.shape[1]), jnp.float32)
    for kb in range(pp.shape[1] // bk):
        xb = pp[:, kb * bk:(kb + 1) * bk].astype(jnp.float32)
        x_q, scale = quant_rows(xb)
        out = cim_lib.cim_matmul_model(x_q, wp[kb * bk:(kb + 1) * bk], cfg)
        acc = acc + out * scale
    return acc


def trunk_conv_ref(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                   cfg: cim_lib.CiMConfig, stride: int = 1,
                   padding: str = "SAME", block_k: int = 512) -> jax.Array:
    """Oracle for kernels.trunk_conv (float-in fused trunk conv)."""
    kh, kw, c_in, c_out = w_q.shape
    patches, (oh, ow) = cim_lib.im2col(x, kh, kw, stride, padding)
    p = patches.reshape(-1, kh * kw * c_in)
    acc = _blocked_cim_trunk(p, w_q.reshape(-1, c_out), cfg, block_k)
    out = acc * w_scale.reshape(1, -1).astype(jnp.float32)
    return out.reshape(x.shape[0], oh, ow, c_out).astype(x.dtype)


def rebranch_conv_ref(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                      c: jax.Array, core: jax.Array, u: jax.Array,
                      cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(mode="ideal"),
                      stride: int = 1, padding: str = "SAME",
                      block_k: int = 512) -> jax.Array:
    """Oracle for kernels.rebranch_conv: blocked-quant trunk + the UNfused
    branch (1x1 compress -> KxK core -> 1x1 decompress as three XLA convs),
    proving the fused patch-matrix branch identity."""
    from repro.core.rebranch import conv_nhwc

    trunk = trunk_conv_ref(x, w_q, w_scale, cfg, stride, padding, block_k)
    t = conv_nhwc(x.astype(jnp.float32), c.astype(jnp.float32), 1, padding)
    t = conv_nhwc(t, core.astype(jnp.float32), stride, padding)
    branch = conv_nhwc(t, u.astype(jnp.float32), 1, padding)
    return (trunk.astype(jnp.float32) + branch).astype(x.dtype)
