"""Tiling resolution shared by the Pallas kernels.

Each kernel entry point accepts ``block_m/block_n/block_k`` (``None`` =
"consult the tuning table"), ``dim_order`` and an ``impl`` choice between
the ``pallas_call`` grid and the direct plain-XLA lowering.  This module
centralises the precedence rules:

  explicit caller args  >  tuning-table entry  >  per-kernel defaults

plus the one safety invariant the table must never violate: a table
``block_k`` may only be used when it induces the *same k-partition* as
the kernel default.  The k-partition determines the per-block activation
quantisation scales and the accumulation grouping, i.e. the bits of the
result.  Sharded and unsharded invocations of the same conv see
different ``m`` and therefore different table keys, and the sharded
trunk contract is bit-identity — so any tiling the table may hand out
has to be bit-neutral.  block_m/block_n/dim_order/impl always are;
block_k is checked here (and the autotuner only emits legal values, so
this check is a belt-and-braces guard against hand-edited tables).
"""

from __future__ import annotations

import jax

from repro.tune import table as tune_table
from repro.tune.table import Tiling

__all__ = ["Tiling", "resolve_tiling", "resolve_direct", "k_partition",
           "grid_and_axes", "conv_index_maps"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def k_partition(k: int, block_k: int, rows: int) -> tuple[tuple[int, int], ...]:
    """The (start, end) k-ranges a kernel splits the contraction into.

    Mirrors the kernels' clamp rule ``bk = min(block_k, round_up(k, rows))``
    so two block_k values compare equal iff they group the contraction
    identically (identical per-block quant scales and accumulation order).
    """
    bk = min(block_k, _round_up(k, rows))
    gk = -(-k // bk)
    return tuple((b * bk, min((b + 1) * bk, k)) for b in range(gk))


def resolve_tiling(kernel: str, mode: str, dtype: str,
                   m: int, k: int, n: int, *,
                   block_m: int | None, block_n: int | None,
                   block_k: int | None,
                   defaults: tuple[int, int, int],
                   rows: int) -> Tiling:
    """Resolve the tiling for one kernel invocation.

    Explicit (non-``None``) caller block sizes win outright and disable
    the table lookup — a caller pinning any block size gets exactly what
    it asked for (the block-invariance tests rely on this).  Otherwise
    the tuning table is consulted, subject to the k-partition guard.
    """
    dm, dn, dk = defaults
    t = None
    if block_m is None and block_n is None and block_k is None:
        t = tune_table.lookup(kernel, mode, dtype, int(m), int(k), int(n))
        if t is not None and (k_partition(k, t.block_k, rows)
                              != k_partition(k, dk, rows)):
            t = None          # table entry would change the bits: ignore it
    if t is None:
        t = Tiling(block_m=dm, block_n=dn, block_k=dk)
    return Tiling(
        block_m=block_m if block_m is not None else t.block_m,
        block_n=block_n if block_n is not None else t.block_n,
        block_k=block_k if block_k is not None else t.block_k,
        dim_order=t.dim_order, impl=t.impl)


def resolve_direct(interpret: bool | None, direct: bool | None,
                   tiling: Tiling | None = None) -> bool:
    """Decide between the direct XLA lowering and ``pallas_call``.

    ``direct`` is an explicit override; an explicit ``interpret`` flag
    means the caller wants the real ``pallas_call`` grid (the kernel
    tests exercise it this way); otherwise the table's ``impl`` and the
    backend decide — off-TPU, ``pallas_call`` only runs in interpret
    mode, so the direct lowering is the default fast path.
    """
    if direct is not None:
        return bool(direct)
    if interpret is not None:
        return False
    if tiling is not None and tiling.impl == "direct":
        return True
    return jax.default_backend() != "tpu"


def grid_and_axes(gm: int, gn: int, gk: int,
                  dim_order: str) -> tuple[tuple[int, int, int], int, int, int]:
    """Grid tuple plus (m_axis, n_axis, k_axis) for a dim order.

    ``"mnk"`` keeps K innermost (sequential accumulation over K for a
    fixed output tile), ``"kmn"`` hoists K outermost (all output tiles
    touched per K step).  Both visit each output tile's K blocks in
    ascending order, so the accumulated bits are identical.
    """
    if dim_order == "mnk":
        return (gm, gn, gk), 0, 1, 2
    if dim_order == "kmn":
        return (gk, gm, gn), 1, 2, 0
    raise ValueError(f"unknown dim_order {dim_order!r}")


def conv_index_maps(dim_order: str):
    """BlockSpec index maps (x, w, out) for a (M,K)x(K,N) grid kernel."""
    if dim_order == "mnk":
        return (lambda i, j, kk: (i, kk),
                lambda i, j, kk: (kk, j),
                lambda i, j, kk: (i, j))
    if dim_order == "kmn":
        return (lambda kk, i, j: (i, kk),
                lambda kk, i, j: (kk, j),
                lambda kk, i, j: (i, j))
    raise ValueError(f"unknown dim_order {dim_order!r}")
