"""Pallas TPU kernels for the CiM compute hot-spots (+ pure-jnp oracles).

cim_matmul      : the ROM-CiM macro (subarray tiling, bit-serial, 5-bit ADC)
rebranch_matmul : fused frozen-trunk int8 + low-rank branch matmul
cim_conv        : the macro on im2col conv patches (paper §4.1 CNN trunks)
trunk_conv      : frozen-trunk conv, in-VMEM act quantisation, STE backward
rebranch_conv   : fused trunk conv + 1x1 compress sketch in one patch pass

Dispatch: models never call these directly — every frozen-trunk matmul
and conv resolves ``ReBranchSpec.trunk_impl`` through the TrunkEngine
registry (``repro.engine``), where these kernels are registered as the
``'pallas'`` engine (one fused pass: quantise in VMEM, int8 MXU dots,
per-channel scale — and, via the engine's ConvEpilogue hook, folded
BN/bias/activation — the deployment fast path on TPU, interpret mode
elsewhere).  The stock alternatives are ``'int8_native'`` (the pure-jnp
core.cim macro model, exact fidelity control, runs anywhere) and
``'dequant'`` (the paper-faithful XLA float baseline).  Resolution is
strict — unknown names raise with the registered set — and new backends
plug in with ``repro.engine.register`` without touching model code;
``repro.deploy.compile_model`` maps engines per layer on top.
"""

from repro.kernels.ops import (
    cim_matmul, rebranch_matmul, trunk_matmul_pallas,
    cim_conv, rebranch_conv, trunk_conv,
)
from repro.kernels import ref

__all__ = [
    "cim_matmul", "rebranch_matmul", "trunk_matmul_pallas",
    "cim_conv", "rebranch_conv", "trunk_conv", "ref",
]
