"""Pallas TPU kernels for the CiM compute hot-spots (+ pure-jnp oracles).

cim_matmul      : the ROM-CiM macro (subarray tiling, bit-serial, 5-bit ADC)
rebranch_matmul : fused frozen-trunk int8 + low-rank branch matmul
"""

from repro.kernels.ops import cim_matmul, rebranch_matmul, trunk_matmul_pallas
from repro.kernels import ref

__all__ = ["cim_matmul", "rebranch_matmul", "trunk_matmul_pallas", "ref"]
