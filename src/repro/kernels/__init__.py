"""Pallas TPU kernels for the CiM compute hot-spots (+ pure-jnp oracles).

cim_matmul      : the ROM-CiM macro (subarray tiling, bit-serial, 5-bit ADC)
rebranch_matmul : fused frozen-trunk int8 + low-rank branch matmul
cim_conv        : the macro on im2col conv patches (paper §4.1 CNN trunks)
trunk_conv      : frozen-trunk conv, in-VMEM act quantisation, STE backward
rebranch_conv   : fused trunk conv + 1x1 compress sketch in one patch pass

Trunk dispatch table (``ReBranchSpec.trunk_impl``), for linears AND convs:

  'int8_native' : pure-jnp CiM macro model (core.cim) on int8 operands —
                  the default; exact fidelity control, runs anywhere, and
                  what accuracy studies should use.
  'dequant'     : dequantise the ROM image and run a plain XLA matmul/conv
                  on fake-quantised activations — the paper-faithful
                  baseline the perf work is measured against.
  'pallas'      : these kernels — one fused pass (quantise in VMEM, int8
                  MXU dots, scale epilogue); the deployment fast path on
                  TPU, interpret-mode elsewhere.
"""

from repro.kernels.ops import (
    cim_matmul, rebranch_matmul, trunk_matmul_pallas,
    cim_conv, rebranch_conv, trunk_conv,
)
from repro.kernels import ref

__all__ = [
    "cim_matmul", "rebranch_matmul", "trunk_matmul_pallas",
    "cim_conv", "rebranch_conv", "trunk_conv", "ref",
]
