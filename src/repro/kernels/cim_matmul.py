"""Pallas TPU kernel: the ROM-CiM macro matmul (paper §3.1, Fig. 5).

TPU-native adaptation of the analogue macro: the 128-row subarray becomes
the K-block of the BlockSpec tiling; each bit-line partial sum is an MXU
dot over one subarray slice; the 5-bit ADC transfer function is applied to
partial sums in VMEM before shift-add recombination into the accumulator.

Grid: (M/bm, N/bn, K/bk) with K innermost so the f32 accumulator block
stays resident in VMEM across the contraction.  bk is a multiple of 128
(``rows_per_subarray``) so subarray boundaries align with the global K
offsets — the kernel is bit-compatible with core.cim.cim_matmul_model.

Modes: 'ideal' (plain int8 MXU dot -> int32 — the deployment fast path),
'per_subarray', 'bitserial' (fidelity simulation, same math as core.cim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import adc as adc_lib
from repro.core import cim as cim_lib
from repro.kernels.tiling import (conv_index_maps, grid_and_axes,
                                  resolve_direct, resolve_tiling)

# The ADC transfer functions are the SAME objects the pure-jnp macro model
# uses (core.adc) — the comparator convention cannot drift between the
# oracle and the kernel.
_adc = adc_lib.adc_transfer
_signed_adc = adc_lib.signed_adc


def _dot_f32(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _dot_int8(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def cim_block_dot(cfg: cim_lib.CiMConfig, x, w):
    """Mode-dependent macro math for one VMEM block: int8 (bm, bk) x
    int8 (bk, bn) -> f32 (bm, bn).

    bk must hold whole 128-row subarrays (bk % rows == 0) so subarray
    boundaries align with global K offsets — this is what keeps any kernel
    built on this helper bit-compatible with core.cim.cim_matmul_model.
    Shared by the matmul kernel here and the fused conv kernel in
    rebranch_conv.py.
    """
    rows = cfg.rows_per_subarray

    if cfg.mode == "ideal":
        return _dot_int8(x, w).astype(jnp.float32)

    if cfg.mode == "per_subarray":
        s = x.shape[1] // rows
        full_range = rows * 127.0
        acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
        for si in range(s):
            xs = x[:, si * rows:(si + 1) * rows].astype(jnp.float32)
            ws = w[si * rows:(si + 1) * rows, :].astype(jnp.float32)
            acc = acc + _signed_adc(_dot_f32(xs, ws), full_range, cfg)
        return acc

    if cfg.mode == "bitserial":
        s = x.shape[1] // rows
        mag_bits, act_groups, gmax = adc_lib.bitserial_planes(cfg)
        x_i = x.astype(jnp.int32)
        w_i = w.astype(jnp.int32)
        acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
        for sa, a_part in ((0, jnp.maximum(x_i, 0)), (1, jnp.maximum(-x_i, 0))):
            for sw, w_part in ((0, jnp.maximum(w_i, 0)),
                               (1, jnp.maximum(-w_i, 0))):
                sign = 1.0 if sa == sw else -1.0
                for si in range(s):
                    a_s = a_part[:, si * rows:(si + 1) * rows]
                    w_s = w_part[si * rows:(si + 1) * rows, :]
                    for g in range(act_groups):
                        a_g = ((a_s >> (g * cfg.act_group_bits)) & gmax
                               ).astype(jnp.float32)
                        for j in range(mag_bits):
                            w_j = ((w_s >> j) & 1).astype(jnp.float32)
                            counts = _dot_f32(a_g, w_j)
                            # tape-out-known per-column sense references
                            popcount = jnp.sum(w_j, axis=0, keepdims=True)
                            rng = jnp.maximum(popcount * gmax, 1.0)
                            sensed = _adc(counts, rng, cfg)
                            acc = acc + sign * (4.0 ** g) * (2.0 ** j) * sensed
        return acc

    raise ValueError(f"unknown CiM mode: {cfg.mode!r}")


def _cim_kernel(cfg: cim_lib.CiMConfig, k_axis: int, x_ref, w_ref, o_ref):
    """One (bm, bn) output block; K accumulated across grid axis k_axis."""

    @pl.when(pl.program_id(k_axis) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += cim_block_dot(cfg, x_ref[...], w_ref[...])


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("cfg", "bk"))
def _cim_direct(x_q, w_q, *, cfg, bk):
    """Plain-XLA lowering of the grid kernel's block decomposition.

    Per K block, integer dots are exact in f32 (``bk * 127 * 127 <
    2**24``) and the cross-block f32 accumulation happens in the same
    ascending-K order the grid uses.  Non-ideal modes pad ragged K
    blocks with zero subarrays, which contribute exactly 0 through
    every ADC path (adc(0) == 0).  Jitted as its own compilation unit
    so eager callers dispatch one executable, and the multi-block
    accumulate runs under ``lax.scan`` so the bits survive a caller's
    jit too: an outer jit inlines the inner jit and XLA fuses an
    unrolled accumulate with caller ops (consumer-dependent FMA
    contraction perturbs the last ulp — ``optimization_barrier`` is
    dropped by the CPU pipeline before fusion), whereas a scan body is
    its own fusion domain, compiled identically in every context.
    """
    m, k = x_q.shape
    n = w_q.shape[1]
    gk = -(-k // bk)
    if gk == 1:
        if cfg.mode == "ideal":
            return _dot_f32(x_q.astype(jnp.float32),
                            w_q.astype(jnp.float32))
        return cim_block_dot(cfg, x_q, w_q)
    pad_k = gk * bk - k
    xp = jnp.pad(x_q, ((0, 0), (0, pad_k)))
    wp = jnp.pad(w_q, ((0, pad_k), (0, 0)))
    if cfg.mode == "ideal":
        xp, wp = xp.astype(jnp.float32), wp.astype(jnp.float32)

    def body(acc, b):
        xb = jax.lax.dynamic_slice(xp, (0, b * bk), (m, bk))
        wb = jax.lax.dynamic_slice(wp, (b * bk, 0), (bk, n))
        if cfg.mode == "ideal":
            part = _dot_f32(xb, wb)
        else:
            part = cim_block_dot(cfg, xb, wb)
        return acc + part, None

    out, _ = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32),
                          jnp.arange(gk))
    return out


def cim_matmul_pallas(
    x_q: jax.Array,                 # int8 [M, K]
    w_q: jax.Array,                 # int8 [K, N]
    cfg: cim_lib.CiMConfig = cim_lib.DEFAULT_CIM,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,     # default 512: 4 subarrays per VMEM block
    interpret: bool | None = None,
    direct: bool | None = None,
) -> jax.Array:
    """Blocked CiM matmul; returns f32 [M, N] integer-valued results.

    Block sizes left as ``None`` are resolved through the tuning table
    (``repro.tune``); explicit values win outright.  ``direct=True``
    forces the plain-XLA lowering (the off-TPU default), ``direct=False``
    or an explicit ``interpret`` flag forces ``pallas_call``.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    if 0 in (m, n, k):
        return jnp.zeros((m, n), jnp.float32)
    rows = cfg.rows_per_subarray

    t = resolve_tiling("cim_matmul", cfg.mode, str(x_q.dtype), m, k, n,
                       block_m=block_m, block_n=block_n, block_k=block_k,
                       defaults=(128, 128, 512), rows=rows)
    assert t.block_k % rows == 0, "K blocks must hold whole subarrays"
    # Clamp K blocks subarray-aligned: a 300-wide contraction with the
    # 512 default used to pad out to 512 columns; 384 (3 subarrays) is
    # enough and bit-identical (zero subarrays read as 0 in every mode).
    bk = min(t.block_k, _round_up(k, rows))

    if resolve_direct(interpret, direct, t):
        return _cim_direct(x_q, w_q, cfg=cfg, bk=bk)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm, bn = min(t.block_m, m), min(t.block_n, n)
    pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x_q, ((0, pad_m), (0, pad_k)))
    wp = jnp.pad(w_q, ((0, pad_k), (0, pad_n)))
    gm, gn, gk = xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk
    grid, _, _, k_axis = grid_and_axes(gm, gn, gk, t.dim_order)
    x_map, w_map, o_map = conv_index_maps(t.dim_order)

    out = pl.pallas_call(
        functools.partial(_cim_kernel, cfg, k_axis),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((bk, bn), w_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]
