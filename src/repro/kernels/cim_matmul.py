"""Pallas TPU kernel: the ROM-CiM macro matmul (paper §3.1, Fig. 5).

TPU-native adaptation of the analogue macro: the 128-row subarray becomes
the K-block of the BlockSpec tiling; each bit-line partial sum is an MXU
dot over one subarray slice; the 5-bit ADC transfer function is applied to
partial sums in VMEM before shift-add recombination into the accumulator.

Grid: (M/bm, N/bn, K/bk) with K innermost so the f32 accumulator block
stays resident in VMEM across the contraction.  bk is a multiple of 128
(``rows_per_subarray``) so subarray boundaries align with the global K
offsets — the kernel is bit-compatible with core.cim.cim_matmul_model.

Modes: 'ideal' (plain int8 MXU dot -> int32 — the deployment fast path),
'per_subarray', 'bitserial' (fidelity simulation, same math as core.cim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import adc as adc_lib
from repro.core import cim as cim_lib

# The ADC transfer functions are the SAME objects the pure-jnp macro model
# uses (core.adc) — the comparator convention cannot drift between the
# oracle and the kernel.
_adc = adc_lib.adc_transfer
_signed_adc = adc_lib.signed_adc


def _dot_f32(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _dot_int8(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def cim_block_dot(cfg: cim_lib.CiMConfig, x, w):
    """Mode-dependent macro math for one VMEM block: int8 (bm, bk) x
    int8 (bk, bn) -> f32 (bm, bn).

    bk must hold whole 128-row subarrays (bk % rows == 0) so subarray
    boundaries align with global K offsets — this is what keeps any kernel
    built on this helper bit-compatible with core.cim.cim_matmul_model.
    Shared by the matmul kernel here and the fused conv kernel in
    rebranch_conv.py.
    """
    rows = cfg.rows_per_subarray

    if cfg.mode == "ideal":
        return _dot_int8(x, w).astype(jnp.float32)

    if cfg.mode == "per_subarray":
        s = x.shape[1] // rows
        full_range = rows * 127.0
        acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
        for si in range(s):
            xs = x[:, si * rows:(si + 1) * rows].astype(jnp.float32)
            ws = w[si * rows:(si + 1) * rows, :].astype(jnp.float32)
            acc = acc + _signed_adc(_dot_f32(xs, ws), full_range, cfg)
        return acc

    if cfg.mode == "bitserial":
        s = x.shape[1] // rows
        mag_bits, act_groups, gmax = adc_lib.bitserial_planes(cfg)
        x_i = x.astype(jnp.int32)
        w_i = w.astype(jnp.int32)
        acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
        for sa, a_part in ((0, jnp.maximum(x_i, 0)), (1, jnp.maximum(-x_i, 0))):
            for sw, w_part in ((0, jnp.maximum(w_i, 0)),
                               (1, jnp.maximum(-w_i, 0))):
                sign = 1.0 if sa == sw else -1.0
                for si in range(s):
                    a_s = a_part[:, si * rows:(si + 1) * rows]
                    w_s = w_part[si * rows:(si + 1) * rows, :]
                    for g in range(act_groups):
                        a_g = ((a_s >> (g * cfg.act_group_bits)) & gmax
                               ).astype(jnp.float32)
                        for j in range(mag_bits):
                            w_j = ((w_s >> j) & 1).astype(jnp.float32)
                            counts = _dot_f32(a_g, w_j)
                            # tape-out-known per-column sense references
                            popcount = jnp.sum(w_j, axis=0, keepdims=True)
                            rng = jnp.maximum(popcount * gmax, 1.0)
                            sensed = _adc(counts, rng, cfg)
                            acc = acc + sign * (4.0 ** g) * (2.0 ** j) * sensed
        return acc

    raise ValueError(f"unknown CiM mode: {cfg.mode!r}")


def _cim_kernel(cfg: cim_lib.CiMConfig, x_ref, w_ref, o_ref):
    """One (bm, bn) output block; K accumulated across grid axis 2."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += cim_block_dot(cfg, x_ref[...], w_ref[...])


def cim_matmul_pallas(
    x_q: jax.Array,                 # int8 [M, K]
    w_q: jax.Array,                 # int8 [K, N]
    cfg: cim_lib.CiMConfig = cim_lib.DEFAULT_CIM,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,             # 4 subarrays per VMEM block
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked CiM matmul; returns f32 [M, N] integer-valued results."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    if 0 in (m, n, k):
        return jnp.zeros((m, n), jnp.float32)
    rows = cfg.rows_per_subarray
    assert block_k % rows == 0, "K blocks must hold whole subarrays"

    bm, bn, bk = min(block_m, m), min(block_n, n), block_k
    pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x_q, ((0, pad_m), (0, pad_k)))
    wp = jnp.pad(w_q, ((0, pad_k), (0, pad_n)))
    gm, gn, gk = xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_cim_kernel, cfg),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]
