"""Jit'd public wrappers for the Pallas kernels (+ STE backward rules).

These are the primitives behind the ``'pallas'`` TrunkEngine
(repro.engine.builtin.PallasEngine); layers reach them via
``repro.engine.resolve(spec)``, never by string comparison.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim as cim_lib
from repro.core import quant
from repro.core.rebranch import trunk_conv_residuals, trunk_conv_ste_bwd
from repro.kernels.cim_matmul import cim_matmul_pallas
from repro.kernels.rebranch_conv import (
    cim_conv_pallas, rebranch_conv_pallas, trunk_conv_pallas as
    _trunk_conv_pallas_fwd,
)
from repro.kernels.rebranch_matmul import rebranch_matmul_pallas


@functools.partial(jax.jit, static_argnames=("cfg",))
def cim_matmul(x_q, w_q, cfg: cim_lib.CiMConfig = cim_lib.DEFAULT_CIM):
    """int8 x int8 CiM matmul via the Pallas macro-simulation kernel."""
    return cim_matmul_pallas(x_q, w_q, cfg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def trunk_matmul_pallas(cfg: cim_lib.CiMConfig, x, w_q, w_scale):
    """Frozen-trunk matmul on the Pallas CiM kernel with an STE backward.

    Drop-in for core.rebranch.trunk_matmul (the 'pallas' engine's matmul).
    """
    x_q, sx = quant.quantize_activations(x)
    lead = x_q.shape[:-1]           # kernel is 2D; flatten [..., K] -> [M, K]
    out = cim_matmul_pallas(x_q.reshape(-1, x_q.shape[-1]), w_q, cfg)
    out = out.reshape(*lead, out.shape[-1])
    return (out * sx).astype(x.dtype) * w_scale.astype(x.dtype)


def _fwd(cfg, x, w_q, w_scale):
    return trunk_matmul_pallas(cfg, x, w_q, w_scale), (w_q, w_scale)


def _bwd(cfg, res, g):
    w_q, w_scale = res
    w_deq = w_q.astype(g.dtype) * w_scale.astype(g.dtype)
    dx = jnp.einsum("...n,kn->...k", g, w_deq)
    zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, zero(w_q), zero(w_scale)


trunk_matmul_pallas.defvjp(_fwd, _bwd)


@functools.partial(jax.jit, static_argnames=("cfg",))
def rebranch_matmul(x, w_q, w_scale, c, core, u,
                    cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(mode="ideal")):
    """Fused trunk+branch ReBranch layer forward (beyond-paper fast path)."""
    return rebranch_matmul_pallas(x, w_q, w_scale, c, core, u, cfg)


# ---------------------------------------------------------------------------
# convolution primitives (the 'pallas' engine's conv path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "stride", "padding"))
def cim_conv(x_q, w_q, cfg: cim_lib.CiMConfig = cim_lib.DEFAULT_CIM,
             stride: int = 1, padding: str = "SAME"):
    """int8 x int8 CiM convolution via the Pallas im2col macro kernel."""
    return cim_conv_pallas(x_q, w_q, cfg, stride=stride, padding=padding)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def trunk_conv(cfg: cim_lib.CiMConfig, stride: int, padding: str,
               x, w_q, w_scale):
    """Frozen-trunk convolution on the Pallas CiM kernel, STE backward.

    Drop-in for core.rebranch.trunk_conv (the 'pallas' engine's conv);
    activation quantisation happens in VMEM at per-(patch-row, k-block)
    granularity inside the fused kernel.
    """
    return _trunk_conv_pallas_fwd(x, w_q, w_scale, cfg,
                                  stride=stride, padding=padding)


def _conv_fwd(cfg, stride, padding, x, w_q, w_scale):
    out = trunk_conv(cfg, stride, padding, x, w_q, w_scale)
    return out, trunk_conv_residuals(x, w_q, w_scale)


def _conv_bwd(cfg, stride, padding, res, g):
    return trunk_conv_ste_bwd(stride, padding, res, g)


trunk_conv.defvjp(_conv_fwd, _conv_bwd)


@functools.partial(jax.jit, static_argnames=("cfg", "stride", "padding"))
def rebranch_conv(x, w_q, w_scale, c, core, u,
                  stride: int = 1, padding: str = "SAME",
                  cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(mode="ideal")):
    """Fused trunk+branch ReBranch conv forward (beyond-paper fast path)."""
    return rebranch_conv_pallas(x, w_q, w_scale, c, core, u, cfg,
                                stride=stride, padding=padding)
