"""Jit'd public wrappers for the Pallas kernels (+ STE backward rules)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim as cim_lib
from repro.core import quant
from repro.kernels.cim_matmul import cim_matmul_pallas
from repro.kernels.rebranch_matmul import rebranch_matmul_pallas


@functools.partial(jax.jit, static_argnames=("cfg",))
def cim_matmul(x_q, w_q, cfg: cim_lib.CiMConfig = cim_lib.DEFAULT_CIM):
    """int8 x int8 CiM matmul via the Pallas macro-simulation kernel."""
    return cim_matmul_pallas(x_q, w_q, cfg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def trunk_matmul_pallas(cfg: cim_lib.CiMConfig, x, w_q, w_scale):
    """Frozen-trunk matmul on the Pallas CiM kernel with an STE backward.

    Drop-in for core.rebranch.trunk_matmul (spec.trunk_impl == 'pallas').
    """
    x_q, sx = quant.quantize_activations(x)
    out = cim_matmul_pallas(x_q, w_q, cfg)
    return (out * sx).astype(x.dtype) * w_scale.astype(x.dtype)


def _fwd(cfg, x, w_q, w_scale):
    return trunk_matmul_pallas(cfg, x, w_q, w_scale), (w_q, w_scale)


def _bwd(cfg, res, g):
    w_q, w_scale = res
    w_deq = w_q.astype(g.dtype) * w_scale.astype(g.dtype)
    dx = jnp.einsum("...n,kn->...k", g, w_deq)
    zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, zero(w_q), zero(w_scale)


trunk_matmul_pallas.defvjp(_fwd, _bwd)


@jax.jit
def rebranch_matmul(x, w_q, w_scale, c, core, u):
    """Fused trunk+branch ReBranch layer forward (beyond-paper fast path)."""
    return rebranch_matmul_pallas(x, w_q, w_scale, c, core, u)
