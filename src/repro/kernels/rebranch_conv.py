"""Pallas TPU kernel: fused im2col ReBranch convolution (paper §4.1 CNNs).

YOLoC's headline workloads are detection CNNs (VGG-8, ResNet-18,
DarkNet-19, Tiny-YOLO) whose trunk convs live in ROM-CiM.  On TPU a conv
lowers to a matmul over the im2col patch matrix  P [N*OH*OW, KH*KW*C_in],
so the conv kernels here are the conv analogues of cim_matmul /
rebranch_matmul, built on the *same* per-block macro math
(``cim_matmul.cim_block_dot``) — bit-compatible with
``core.cim.cim_conv_model`` in every fidelity mode.

Three entry points:

cim_conv_pallas      : int8 patches x int8 ROM weights through the macro
                       model (ideal / per_subarray / bitserial) — the conv
                       twin of cim_matmul_pallas.
trunk_conv_pallas    : float activations in; per-(patch-row, k-block)
                       dynamic int8 quantisation happens in VMEM, the int8
                       MXU dot and the per-channel scale epilogue follow in
                       the same pass (the 'pallas' TrunkEngine path).
rebranch_conv_pallas : the fused ReBranch conv — trunk kernel plus the
                       per-tap compress sketch on the SAME patch matrix;
                       the tiny epilogue ``out = trunk*w_scale +
                       (t1 @ core) @ U`` is left to XLA.  Key identity:
                       1x1-compress -> KxK core conv composes into one
                       KxK conv, so the trunk's patch matrix serves the
                       branch exactly.  The compress is STRUCTURED: the
                       patch matrix is tap-major (R = taps*C_in), so

                         t1 = (P.reshape(M*taps, C_in) @ C).reshape(M, taps*C_c)

                       is a plain matmul on a zero-copy reshape — branch
                       FLOPs scale with ``taps`` (an earlier version
                       densified the block-diagonal compress as
                       ``P @ kron(I_taps, C)``, paying ``taps^2``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import cim as cim_lib
from repro.core.quant import quantize_activations
from repro.kernels.cim_matmul import cim_block_dot, cim_matmul_pallas


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _patch_matrix(x: jax.Array, kh: int, kw: int, stride: int, padding: str):
    """im2col + flatten: NHWC -> (P [M, R], (n, oh, ow))."""
    n = x.shape[0]
    patches, (oh, ow) = cim_lib.im2col(x, kh, kw, stride, padding)
    return patches.reshape(n * oh * ow, patches.shape[-1]), (n, oh, ow)


def _quant_rows(x: jax.Array):
    """In-VMEM dynamic int8 quantisation, per (row, k-block) — the same
    quantiser as the int8_native path (pure jnp, safe in a kernel body)."""
    return quantize_activations(x)


# ---------------------------------------------------------------------------
# int8-in conv: the conv twin of cim_matmul_pallas
# ---------------------------------------------------------------------------

def cim_conv_pallas(
    x_q: jax.Array,                 # int8 [N, H, W, C_in]
    w_q: jax.Array,                 # int8 [KH, KW, C_in, C_out]
    cfg: cim_lib.CiMConfig = cim_lib.DEFAULT_CIM,
    *,
    stride: int = 1,
    padding: str = "SAME",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked CiM conv; returns f32 [N, OH, OW, C_out] integer-valued
    results, bit-compatible with core.cim.cim_conv_model."""
    kh, kw, c_in, c_out = w_q.shape
    p, (n, oh, ow) = _patch_matrix(x_q, kh, kw, stride, padding)
    # clamp K blocks to the (subarray-aligned) patch width so small-R convs
    # (e.g. a 3x3x3 stem, R=27) don't pad the contraction out to block_k
    rows = cfg.rows_per_subarray
    bk = min(block_k, _round_up(kh * kw * c_in, rows))
    out = cim_matmul_pallas(
        p, w_q.reshape(kh * kw * c_in, c_out), cfg,
        block_m=block_m, block_n=block_n, block_k=bk,
        interpret=interpret)
    return out.reshape(n, oh, ow, c_out)


# ---------------------------------------------------------------------------
# float-in trunk conv: in-VMEM quantisation + macro dot + scale epilogue
# ---------------------------------------------------------------------------

def _trunk_conv_kernel(cfg, x_ref, wq_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, bk) patch slab
    x_q, scale = _quant_rows(x)
    o_ref[...] += cim_block_dot(cfg, x_q, wq_ref[...]) * scale


def _conv_blocks(m: int, r: int, c_out: int, bm: int, bn: int, bk: int,
                 rows: int):
    """Clamp block sizes to the problem and align K blocks to subarrays."""
    assert bk % rows == 0, "K blocks must hold whole subarrays"
    bk = min(bk, _round_up(r, rows))
    return min(bm, m), min(bn, c_out), bk


def _trunk_patch_dot(p, w2d, cfg, block_m, block_n, block_k, interpret):
    """Blocked Pallas trunk pass over the flat patch matrix.

    p [M, R] float patches, w2d [R, C_out] int8 — returns the UNscaled f32
    trunk accumulation [M, C_out] (callers apply ``w_scale``).  K blocks
    stay subarray-aligned so the macro fidelity model sees the same row
    grouping as the unblocked oracle.
    """
    m, r = p.shape
    c_out = w2d.shape[1]
    bm, bn, bk = _conv_blocks(m, r, c_out, block_m, block_n, block_k,
                              cfg.rows_per_subarray)
    pad_m, pad_n, pad_k = (-m) % bm, (-c_out) % bn, (-r) % bk
    pp = jnp.pad(p, ((0, pad_m), (0, pad_k)))
    wp = jnp.pad(w2d, ((0, pad_k), (0, pad_n)))
    gm, gn, gk = pp.shape[0] // bm, wp.shape[1] // bn, pp.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_trunk_conv_kernel, cfg),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pp.shape[0], wp.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(pp, wp)
    return out[:m, :c_out]


def trunk_conv_pallas(
    x: jax.Array,                   # [N, H, W, C_in] float
    w_q: jax.Array,                 # int8 [KH, KW, C_in, C_out] (ROM)
    w_scale: jax.Array,             # per-output-channel f32
    cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(mode="ideal"),
    *,
    stride: int = 1,
    padding: str = "SAME",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Frozen-trunk convolution, quantisation fused into the macro pass."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kh, kw, c_in, c_out = w_q.shape
    p, (n, oh, ow) = _patch_matrix(x, kh, kw, stride, padding)
    if p.shape[0] == 0:
        return jnp.zeros((n, oh, ow, c_out), x.dtype)
    out = _trunk_patch_dot(p, w_q.reshape(-1, c_out), cfg,
                           block_m, block_n, block_k, interpret)
    out = out * w_scale.reshape(1, -1).astype(jnp.float32)
    return out.reshape(n, oh, ow, c_out).astype(x.dtype)


# ---------------------------------------------------------------------------
# fused ReBranch conv: trunk + structured compress on the shared patches
# ---------------------------------------------------------------------------

def structured_compress(p: jax.Array, c2d: jax.Array, taps: int) -> jax.Array:
    """Per-tap compress sketch of a tap-major patch matrix.

    p [M, taps*C_in] -> t1 [M, taps*C_c] with  t1[m, t*C_c+j] =
    P[m, t*C_in:(t+1)*C_in] @ C[:, j].  The patch matrix is tap-major, so
    the per-tap dot is a plain matmul on a ZERO-COPY reshape — FLOPs are
    M * taps * C_in * C_c, scaling with ``taps`` (the dense
    ``P @ kron(I_taps, C)`` form costs taps^2).
    """
    m = p.shape[0]
    c_in, c_c = c2d.shape
    t1 = jax.lax.dot_general(
        p.reshape(m * taps, c_in).astype(jnp.float32),
        c2d.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return t1.reshape(m, taps * c_c)


def rebranch_conv_pallas(
    x: jax.Array,                   # [N, H, W, C_in] float
    w_q: jax.Array,                 # int8 [KH, KW, C_in, C_out] trunk (ROM)
    w_scale: jax.Array,             # per-output-channel f32
    c: jax.Array,                   # [1, 1, C_in, C_c] fixed compress (ROM)
    core: jax.Array,                # [KH, KW, C_c, C_u] trainable (SRAM)
    u: jax.Array,                   # [1, 1, C_u, C_out] fixed decompress (ROM)
    cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(mode="ideal"),
    *,
    stride: int = 1,
    padding: str = "SAME",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused ReBranch convolution forward (beyond-paper fast path).

    The 1x1-compress -> KxK-core branch composes into one KxK conv, so
    the trunk dot and the compress sketch share ONE im2col patch matrix:
      trunk[m, n] += macro(quant_blk(P), w_q) * scale_blk   (Pallas grid)
      t1          = structured_compress(P, C)               (MXU matmul)
      out         = trunk * w_scale + (t1 @ core_flat) @ U  (tiny epilogue)

    The compress is the per-tap structured dot (see
    :func:`structured_compress`): branch sketch FLOPs scale with ``taps``,
    not ``taps^2`` as the old ``kron(I_taps, C)`` densification did.  It
    runs as a plain XLA matmul on a zero-copy reshape of the patch matrix
    rather than inside the macro grid: the trunk grid re-reads each patch
    block once per output-channel block anyway, so the one extra read is
    noise, and XLA overlaps the small sketch dot with the trunk kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kh, kw, c_in, c_out = w_q.shape
    assert core.shape[:2] == (kh, kw), (core.shape, w_q.shape)
    c_c, c_u = core.shape[2], core.shape[3]
    taps = kh * kw

    p, (n, oh, ow) = _patch_matrix(x, kh, kw, stride, padding)
    if p.shape[0] == 0:
        return jnp.zeros((n, oh, ow, c_out), x.dtype)
    trunk = _trunk_patch_dot(p, w_q.reshape(-1, c_out), cfg,
                             block_m, block_n, block_k, interpret)
    out = trunk * w_scale.reshape(1, -1).astype(jnp.float32)
    t1 = structured_compress(p, c.reshape(c_in, c_c), taps)
    branch = (t1 @ core.reshape(taps * c_c, c_u).astype(jnp.float32)
              ) @ u.reshape(c_u, c_out).astype(jnp.float32)
    return (out + branch).reshape(n, oh, ow, c_out).astype(x.dtype)
