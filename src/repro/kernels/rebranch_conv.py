"""Pallas TPU kernel: fused im2col ReBranch convolution (paper §4.1 CNNs).

YOLoC's headline workloads are detection CNNs (VGG-8, ResNet-18,
DarkNet-19, Tiny-YOLO) whose trunk convs live in ROM-CiM.  On TPU a conv
lowers to a matmul over the im2col patch matrix  P [N*OH*OW, KH*KW*C_in],
so the conv kernels here are the conv analogues of cim_matmul /
rebranch_matmul, built on the *same* per-block macro math
(``cim_matmul.cim_block_dot``) — bit-compatible with
``core.cim.cim_conv_model`` in every fidelity mode.

Three entry points:

cim_conv_pallas      : int8 patches x int8 ROM weights through the macro
                       model (ideal / per_subarray / bitserial) — the conv
                       twin of cim_matmul_pallas.
trunk_conv_pallas    : float activations in; per-(patch-row, k-block)
                       dynamic int8 quantisation happens in VMEM, the int8
                       MXU dot and the per-channel scale epilogue follow in
                       the same pass (the 'pallas' TrunkEngine path).
rebranch_conv_pallas : the fused ReBranch conv — trunk kernel plus the
                       per-tap compress sketch on the SAME patch matrix;
                       the tiny epilogue ``out = trunk*w_scale +
                       (t1 @ core) @ U`` is left to XLA.  Key identity:
                       1x1-compress -> KxK core conv composes into one
                       KxK conv, so the trunk's patch matrix serves the
                       branch exactly.  The compress is STRUCTURED: the
                       patch matrix is tap-major (R = taps*C_in), so

                         t1 = (P.reshape(M*taps, C_in) @ C).reshape(M, taps*C_c)

                       is a plain matmul on a zero-copy reshape — branch
                       FLOPs scale with ``taps`` (an earlier version
                       densified the block-diagonal compress as
                       ``P @ kron(I_taps, C)``, paying ``taps^2``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import cim as cim_lib
from repro.core.quant import quant_rows
from repro.kernels.cim_matmul import cim_block_dot, cim_matmul_pallas
from repro.kernels.tiling import (Tiling, conv_index_maps, grid_and_axes,
                                  resolve_direct, resolve_tiling)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _patch_matrix(x: jax.Array, kh: int, kw: int, stride: int, padding: str):
    """im2col + flatten: NHWC -> (P [M, R], (n, oh, ow))."""
    n = x.shape[0]
    patches, (oh, ow) = cim_lib.im2col(x, kh, kw, stride, padding)
    return patches.reshape(n * oh * ow, patches.shape[-1]), (n, oh, ow)


def _quant_rows(x: jax.Array):
    """In-VMEM dynamic int8 quantisation, per (row, k-block) — the
    reciprocal-form quantiser (pure jnp, safe in a kernel body; see
    core.quant.quant_rows for the bit-identity argument)."""
    return quant_rows(x)


# ---------------------------------------------------------------------------
# int8-in conv: the conv twin of cim_matmul_pallas
# ---------------------------------------------------------------------------

def cim_conv_pallas(
    x_q: jax.Array,                 # int8 [N, H, W, C_in]
    w_q: jax.Array,                 # int8 [KH, KW, C_in, C_out]
    cfg: cim_lib.CiMConfig = cim_lib.DEFAULT_CIM,
    *,
    stride: int = 1,
    padding: str = "SAME",
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
    direct: bool | None = None,
) -> jax.Array:
    """Blocked CiM conv; returns f32 [N, OH, OW, C_out] integer-valued
    results, bit-compatible with core.cim.cim_conv_model."""
    kh, kw, c_in, c_out = w_q.shape
    p, (n, oh, ow) = _patch_matrix(x_q, kh, kw, stride, padding)
    # K blocks are clamped to the subarray-aligned patch width inside
    # cim_matmul_pallas, so small-R convs (e.g. a 3x3x3 stem, R=27)
    # don't pad the contraction out to block_k.
    out = cim_matmul_pallas(
        p, w_q.reshape(kh * kw * c_in, c_out), cfg,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret, direct=direct)
    return out.reshape(n, oh, ow, c_out)


# ---------------------------------------------------------------------------
# float-in trunk conv: in-VMEM quantisation + macro dot + scale epilogue
# ---------------------------------------------------------------------------

def _trunk_conv_kernel(cfg, k_axis, x_ref, wq_ref, o_ref):
    @pl.when(pl.program_id(k_axis) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, bk) patch slab
    x_q, scale = _quant_rows(x)
    o_ref[...] += cim_block_dot(cfg, x_q, wq_ref[...]) * scale


def _conv_blocks(m: int, r: int, c_out: int, bm: int, bn: int, bk: int,
                 rows: int):
    """Clamp block sizes to the problem and align K blocks to subarrays."""
    assert bk % rows == 0, "K blocks must hold whole subarrays"
    bk = min(bk, _round_up(r, rows))
    return min(bm, m), min(bn, c_out), bk


def _resolve_conv_tiling(x, w_q, cfg, stride, padding,
                         block_m, block_n, block_k) -> Tiling:
    """Tuning-table tiling for a trunk conv's implied patch GEMM."""
    kh, kw, c_in, c_out = w_q.shape
    _, oh = cim_lib.conv_pads(x.shape[1], kh, stride, padding)
    _, ow = cim_lib.conv_pads(x.shape[2], kw, stride, padding)
    return resolve_tiling(
        "trunk_conv", cfg.mode, str(x.dtype),
        x.shape[0] * oh * ow, kh * kw * c_in, c_out,
        block_m=block_m, block_n=block_n, block_k=block_k,
        defaults=(128, 128, 512), rows=cfg.rows_per_subarray)


def _trunk_patch_dot(p, w2d, cfg, t: Tiling, interpret):
    """Blocked Pallas trunk pass over the flat patch matrix.

    p [M, R] float patches, w2d [R, C_out] int8 — returns the UNscaled f32
    trunk accumulation [M, C_out] (callers apply ``w_scale``).  K blocks
    stay subarray-aligned so the macro fidelity model sees the same row
    grouping as the unblocked oracle.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, r = p.shape
    c_out = w2d.shape[1]
    bm, bn, bk = _conv_blocks(m, r, c_out, t.block_m, t.block_n, t.block_k,
                              cfg.rows_per_subarray)
    pad_m, pad_n, pad_k = (-m) % bm, (-c_out) % bn, (-r) % bk
    pp = jnp.pad(p, ((0, pad_m), (0, pad_k)))
    wp = jnp.pad(w2d, ((0, pad_k), (0, pad_n)))
    gm, gn, gk = pp.shape[0] // bm, wp.shape[1] // bn, pp.shape[1] // bk
    grid, _, _, k_axis = grid_and_axes(gm, gn, gk, t.dim_order)
    x_map, w_map, o_map = conv_index_maps(t.dim_order)

    out = pl.pallas_call(
        functools.partial(_trunk_conv_kernel, cfg, k_axis),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((bk, bn), w_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((pp.shape[0], wp.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(pp, wp)
    return out[:m, :c_out]


# ---------------------------------------------------------------------------
# direct (plain-XLA) trunk lowering — the off-TPU fast path
# ---------------------------------------------------------------------------

def _stacked_patches(x, kh, kw, stride, padding):
    """Tap-major patch matrix via stacked strided slices.

    Produces exactly the same P [M, taps*C_in] as :func:`_patch_matrix`
    (tap-major layout), but through kh*kw strided views + one stack —
    much cheaper for XLA:CPU than the gather-based im2col.
    """
    n, h, w, c_in = x.shape
    (ph0, ph1), oh = cim_lib.conv_pads(h, kh, stride, padding)
    (pw0, pw1), ow = cim_lib.conv_pads(w, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    hi = (oh - 1) * stride + 1
    wi = (ow - 1) * stride + 1
    cols = [xp[:, i:i + hi:stride, j:j + wi:stride, :]
            for i in range(kh) for j in range(kw)]
    p = jnp.stack(cols, axis=3).reshape(n * oh * ow, kh * kw * c_in)
    return p, (n, oh, ow), ((ph0, ph1), (pw0, pw1), hi, wi)


def _block_absmaxes(x, p, kh, kw, c_in, stride, pads, bk):
    """Per-(patch-row, k-block) absolute maxima, without widening P.

    Returns ([(k0, k1)], [absmax (M, 1)]) matching the kernel's k-block
    partition.  The maxima are assembled from per-pixel channel maxima
    via shifted-window max reductions when block boundaries allow
    (separable for gk == 1; per-tap windows when blocks hold whole
    taps); the general ragged case falls back to column maxima over P.
    Zero conv padding never raises a max, so all three routes compute
    the exact same numbers the kernel sees in its padded (bm, bk) slab.
    """
    (ph0, ph1), (pw0, pw1), hi, wi = pads
    taps = kh * kw
    m, r = p.shape
    gk = -(-r // bk)
    if gk == 1:
        am = jnp.pad(jnp.max(jnp.abs(x), axis=-1),
                     ((0, 0), (ph0, ph1), (pw0, pw1)))
        mw = am[:, :, 0:wi:stride]
        for j in range(1, kw):
            mw = jnp.maximum(mw, am[:, :, j:j + wi:stride])
        mh = mw[:, 0:hi:stride]
        for i in range(1, kh):
            mh = jnp.maximum(mh, mw[:, i:i + hi:stride])
        return [(0, r)], [mh.reshape(m, 1)]
    if bk % c_in == 0:
        # block boundaries fall on tap boundaries: per-tap channel-max
        # windows, then a max over each block's taps
        am = jnp.pad(jnp.max(jnp.abs(x), axis=-1),
                     ((0, 0), (ph0, ph1), (pw0, pw1)))
        amt = [am[:, i:i + hi:stride, j:j + wi:stride]
               for i in range(kh) for j in range(kw)]
        tpb = bk // c_in
        bounds, absmaxes = [], []
        for b in range(gk):
            t0, t1 = b * tpb, min((b + 1) * tpb, taps)
            blk = amt[t0]
            for t in range(t0 + 1, t1):
                blk = jnp.maximum(blk, amt[t])
            bounds.append((t0 * c_in, min(t1 * c_in, r)))
            absmaxes.append(blk.reshape(m, 1))
        return bounds, absmaxes
    bounds, absmaxes = [], []
    for b in range(gk):
        k0, k1 = b * bk, min((b + 1) * bk, r)
        bounds.append((k0, k1))
        absmaxes.append(jnp.max(jnp.abs(p[:, k0:k1]), axis=1, keepdims=True))
    return bounds, absmaxes


def _direct_trunk_patch_dot(p, bounds, absmaxes, w2d, cfg):
    """Direct lowering of ``_trunk_patch_dot``'s block accumulation.

    Per k-block: the same reciprocal quantisation the kernel applies in
    VMEM, the same macro math (f32 GEMM in ideal mode — exact, block
    dots stay under 2**24 — ``cim_block_dot`` otherwise), accumulated in
    the same ascending-K order.  Ragged tails padded with zero rows read
    as 0 through every ADC path, matching the kernel's padded slabs.

    The multi-block accumulate runs under ``lax.scan``, NOT an unrolled
    add chain: an open ``acc + dot*scale`` elementwise graph is fused by
    XLA with whatever the caller puts next, and the FMA contraction LLVM
    then applies depends on that consumer — the same conv would round
    differently eagerly vs under a caller's jit, breaking the eager/jit
    bit-parity the sharded engine contracts (``optimization_barrier``
    does NOT help: XLA's CPU pipeline drops it before fusion).  A scan
    body is compiled as a while-loop body — its own fusion domain,
    bit-identical in every calling context, the same boundary the
    interpret-mode ``pallas_call`` grid enjoys.

    The per-block ``dot * scale`` parts are computed OUTSIDE the scan on
    ragged static slices and only the adds run inside it: a 64-column
    tail block costs a 64-wide GEMM instead of being zero-padded out to
    ``bk`` (78% wasted MACs on a 576-wide DarkNet-19 patch row).  This
    is value-exact (padded columns quantise to 0 and contribute exact-0
    dot terms; ``adc(0) == 0`` on every fidelity path) and bit-stable:
    a lone mul cannot be FMA-contracted — only the adds can, and those
    stay behind the scan boundary.
    """
    m, r = p.shape
    n = w2d.shape[1]
    gk = len(bounds)
    rows = cfg.rows_per_subarray
    w2f = w2d.astype(jnp.float32)

    def block_part(k0, k1, absmax):
        # reciprocal form throughout — matches quant_rows bit-for-bit
        # (jitted XLA turns /127 into *(1/127) anyway; see core.quant)
        pb = p[:, k0:k1]
        scale = jnp.maximum(absmax, 1e-8) * (1.0 / 127.0)
        if cfg.mode == "ideal":
            return (jnp.round(pb * (1.0 / scale)) @ w2f[k0:k1]) * scale
        q = jnp.clip(jnp.round(pb * (1.0 / scale)),
                     -127.0, 127.0).astype(jnp.int8)
        pad = _round_up(k1 - k0, rows) - (k1 - k0)
        return cim_block_dot(cfg, jnp.pad(q, ((0, 0), (0, pad))),
                             jnp.pad(w2d[k0:k1], ((0, pad), (0, 0)))) * scale

    if gk == 1:
        # single block — no cross-block accumulate to protect (the lone
        # dot*scale's downstream adds all carry exact-zero or post-mul
        # addends, where FMA contraction is value-exact)
        (k0, k1), = bounds
        return block_part(k0, k1, absmaxes[0])
    parts = jnp.stack([block_part(k0, k1, am)
                       for (k0, k1), am in zip(bounds, absmaxes)])
    out, _ = jax.lax.scan(lambda acc, pt: (acc + pt, None),
                          jnp.zeros((m, n), jnp.float32), parts)
    return out


@functools.partial(jax.jit, static_argnames=("cfg", "block_k", "stride",
                                             "padding"))
def _direct_trunk_conv(x, w_q, *, cfg, block_k, stride, padding):
    """Direct trunk conv; returns (unscaled trunk [M, C_out], P).

    Jitted as its own compilation unit so eager callers dispatch one
    executable; the bits are identical whether the caller is eager,
    jitted, or a shard_map body — the sharded trunk's bit-parity
    contract depends on this.  The jit alone does not provide that (an
    outer jit inlines it); the scan inside
    :func:`_direct_trunk_patch_dot` does.
    """
    kh, kw, c_in, c_out = w_q.shape
    rows = cfg.rows_per_subarray
    r = kh * kw * c_in
    bk = min(block_k, _round_up(r, rows))
    xf = x.astype(jnp.float32)     # the grid kernel quantises f32 slabs
    p, _, pads = _stacked_patches(xf, kh, kw, stride, padding)
    bounds, absmaxes = _block_absmaxes(xf, p, kh, kw, c_in, stride, pads, bk)
    out = _direct_trunk_patch_dot(p, bounds, absmaxes,
                                  w_q.reshape(r, c_out), cfg)
    return out, p


def trunk_conv_pallas(
    x: jax.Array,                   # [N, H, W, C_in] float
    w_q: jax.Array,                 # int8 [KH, KW, C_in, C_out] (ROM)
    w_scale: jax.Array,             # per-output-channel f32
    cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(mode="ideal"),
    *,
    stride: int = 1,
    padding: str = "SAME",
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
    direct: bool | None = None,
) -> jax.Array:
    """Frozen-trunk convolution, quantisation fused into the macro pass.

    Block sizes left as ``None`` come from the tuning table
    (``repro.tune``), keyed on this conv's implied patch-GEMM geometry.
    Off-TPU the trunk lowers directly to blocked XLA GEMMs replicating
    the grid kernel's decomposition (``direct``/``interpret`` override).
    """
    kh, kw, c_in, c_out = w_q.shape
    _, oh = cim_lib.conv_pads(x.shape[1], kh, stride, padding)
    _, ow = cim_lib.conv_pads(x.shape[2], kw, stride, padding)
    if x.shape[0] * oh * ow == 0:
        return jnp.zeros((x.shape[0], oh, ow, c_out), x.dtype)
    t = _resolve_conv_tiling(x, w_q, cfg, stride, padding,
                             block_m, block_n, block_k)
    if resolve_direct(interpret, direct, t):
        n = x.shape[0]
        out, _ = _direct_trunk_conv(x, w_q, cfg=cfg, block_k=t.block_k,
                                    stride=stride, padding=padding)
    else:
        p, (n, oh, ow) = _patch_matrix(x, kh, kw, stride, padding)
        out = _trunk_patch_dot(p, w_q.reshape(-1, c_out), cfg, t, interpret)
    out = out * w_scale.reshape(1, -1).astype(jnp.float32)
    return out.reshape(n, oh, ow, c_out).astype(x.dtype)


# ---------------------------------------------------------------------------
# fused ReBranch conv: trunk + structured compress on the shared patches
# ---------------------------------------------------------------------------

def structured_compress(p: jax.Array, c2d: jax.Array, taps: int) -> jax.Array:
    """Per-tap compress sketch of a tap-major patch matrix.

    p [M, taps*C_in] -> t1 [M, taps*C_c] with  t1[m, t*C_c+j] =
    P[m, t*C_in:(t+1)*C_in] @ C[:, j].  The patch matrix is tap-major, so
    the per-tap dot is a plain matmul on a ZERO-COPY reshape — FLOPs are
    M * taps * C_in * C_c, scaling with ``taps`` (the dense
    ``P @ kron(I_taps, C)`` form costs taps^2).  (Folding the compress
    and core into one ``P @ (blkdiag(C) @ core_flat)`` GEMM is
    mathematically equivalent and looks cheaper on paper, but measures
    slower end to end on CPU: the wide folded GEMM forces a second
    288-wide streaming read of P, while this skinny leg stays hot in
    cache behind the trunk dot.)
    """
    m = p.shape[0]
    c_in, c_c = c2d.shape
    t1 = jax.lax.dot_general(
        p.reshape(m * taps, c_in).astype(jnp.float32),
        c2d.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return t1.reshape(m, taps * c_c)


def rebranch_conv_pallas(
    x: jax.Array,                   # [N, H, W, C_in] float
    w_q: jax.Array,                 # int8 [KH, KW, C_in, C_out] trunk (ROM)
    w_scale: jax.Array,             # per-output-channel f32
    c: jax.Array,                   # [1, 1, C_in, C_c] fixed compress (ROM)
    core: jax.Array,                # [KH, KW, C_c, C_u] trainable (SRAM)
    u: jax.Array,                   # [1, 1, C_u, C_out] fixed decompress (ROM)
    cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(mode="ideal"),
    *,
    stride: int = 1,
    padding: str = "SAME",
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
    direct: bool | None = None,
) -> jax.Array:
    """Fused ReBranch convolution forward (beyond-paper fast path).

    The 1x1-compress -> KxK-core branch composes into one KxK conv, so
    the trunk dot and the compress sketch share ONE im2col patch matrix:
      trunk[m, n] += macro(quant_blk(P), w_q) * scale_blk   (Pallas grid)
      t1          = structured_compress(P, C)               (MXU matmul)
      out         = trunk * w_scale + (t1 @ core_flat) @ U  (tiny epilogue)

    The compress is the per-tap structured dot (see
    :func:`structured_compress`): branch sketch FLOPs scale with ``taps``,
    not ``taps^2`` as the old ``kron(I_taps, C)`` densification did.  It
    runs as a plain XLA matmul on a zero-copy reshape of the patch matrix
    rather than inside the macro grid: the trunk grid re-reads each patch
    block once per output-channel block anyway, so the one extra read is
    noise, and XLA overlaps the small sketch dot with the trunk kernel.
    """
    kh, kw, c_in, c_out = w_q.shape
    assert core.shape[:2] == (kh, kw), (core.shape, w_q.shape)
    c_c, c_u = core.shape[2], core.shape[3]

    _, oh = cim_lib.conv_pads(x.shape[1], kh, stride, padding)
    _, ow = cim_lib.conv_pads(x.shape[2], kw, stride, padding)
    if x.shape[0] * oh * ow == 0:
        return jnp.zeros((x.shape[0], oh, ow, c_out), x.dtype)
    t = _resolve_conv_tiling(x, w_q, cfg, stride, padding,
                             block_m, block_n, block_k)
    if resolve_direct(interpret, direct, t):
        # trunk and branch share the stacked patch matrix
        n = x.shape[0]
        trunk, p = _direct_trunk_conv(x, w_q, cfg=cfg, block_k=t.block_k,
                                      stride=stride, padding=padding)
    else:
        p, (n, oh, ow) = _patch_matrix(x, kh, kw, stride, padding)
        trunk = _trunk_patch_dot(p, w_q.reshape(-1, c_out), cfg, t, interpret)
    out = trunk * w_scale.reshape(1, -1).astype(jnp.float32)
    t1 = structured_compress(p, c.reshape(c_in, c_c), kh * kw)
    branch = (t1 @ core.reshape(kh * kw * c_c, c_u).astype(jnp.float32)
              ) @ u.reshape(c_u, c_out).astype(jnp.float32)
    return (out + branch).reshape(n, oh, ow, c_out).astype(x.dtype)
