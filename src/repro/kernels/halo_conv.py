"""Halo-exchange sharded convolution: the Pallas conv kernels under
``shard_map`` on NHWC inputs sharded over H.

YOLoC's trunks are *fixed* ROM arrays — scaling serving past one chip
means partitioning the activations, not the weights ("WWW": where to
compute; "Breaking Barriers": array utilisation is the limiter once CiM
fabrics scale out).  A KxK conv's receptive field leaks ``kh-1`` rows
across a spatial cut, so instead of replicating the feature map every
device exchanges only that halo with its neighbours
(``jax.lax.ppermute``) and runs the ordinary fused im2col kernel on its
extended slab.  Wire volume per conv: ``halo_rows * W * C`` per device
pair, vs the full ``H * W * C`` an all-gather would move.

Bit-parity contract: per-device TRUNK results are **bit-identical** to
the unsharded ``trunk_conv_pallas``.  This holds because every per-row
quantity (dynamic int8 quantisation scale, k-block accumulation order,
scale epilogue) depends only on that patch row's values and the
K-blocking — both of which the halo exchange preserves exactly — and the
trunk's f32 accumulators only ever hold exactly-representable integer
partial sums, immune to reduction reassociation.  Missing neighbours
contribute zeros through ``ppermute``, which is precisely the conv's own
SAME zero padding.  The fused ReBranch path matches its unsharded twin
to 1 ulp rather than bitwise: the branch sketch is a genuine float GEMM,
and BLAS reduction order is shape-dependent (local M != global M).

Two geometries, chosen statically by :func:`plan_halo`:

aligned : ``padding='SAME'`` and ``H % (n * stride) == 0`` — shard
          boundaries coincide with output ownership; two-sided halo
          (``ph0`` rows down, ``kh - stride - ph0`` rows up), nothing
          repadded, only halo rows ever cross the wire.  kh=1 convs
          exchange nothing at all (the no-halo fast path).
general : any other H/stride/padding (odd H, VALID, uneven shards) —
          the global top padding plus alignment rows are materialised
          once so every shard starts exactly at its first output row's
          receptive field; the (<= kh - stride)-row bottom halo still
          moves by ``ppermute``.  Surplus output rows are sliced off
          after the shard_map.

``plan_halo`` returns None when a halo would span more than one
neighbour shard (H too small for the mesh); callers fall back to the
unsharded kernel — still correct, just not sharded.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import cim as cim_lib
from repro.core.cim import conv_pads
from repro.core.rebranch import trunk_conv_residuals, trunk_conv_ste_bwd
from repro.kernels.rebranch_conv import (
    rebranch_conv_pallas, trunk_conv_pallas,
)

try:                                     # jax >= 0.5
    shard_map = jax.shard_map
except AttributeError:                   # jax < 0.5: experimental home
    from jax.experimental.shard_map import shard_map


@dataclasses.dataclass(frozen=True)
class HaloPlan:
    """Static geometry of one H-sharded conv (all fields trace-static).

    top/bot: halo rows received from the previous/next device on the
        mesh axis (the buffers ``ppermute`` moves; edge devices receive
        zeros, which is the conv's own zero padding).
    pad_top/pad_bot: zero rows materialised globally before the
        shard_map (general path only; 0/0 on the aligned path).
    oh: true output rows; ol: output rows computed per device
        (``n * ol > oh`` means the tail rows are sliced off afterwards).
    """
    n: int
    aligned: bool
    top: int
    bot: int
    pad_top: int
    pad_bot: int
    oh: int
    ol: int


def plan_halo(h: int, kh: int, stride: int, padding: str,
              n: int) -> HaloPlan | None:
    """Halo geometry for H rows / KHxK kernel sharded n ways, or None when
    a halo would span more than one neighbour shard (fall back unsharded).
    """
    (ph0, _), oh = conv_pads(h, kh, stride, padding)
    if padding == "SAME" and h % (n * stride) == 0:
        hl = h // n
        top, bot = ph0, max(kh - stride - ph0, 0)
        if max(top, bot) > hl:
            return None
        return HaloPlan(n=n, aligned=True, top=top, bot=bot,
                        pad_top=0, pad_bot=0, oh=oh, ol=oh // n)
    # general path: ol covers both the outputs (ceil(oh/n)) and the
    # materialised input rows (ceil((ph0+h)/(n*stride))) so no real row is
    # ever truncated into the zero-filled edge halo
    ol = max(-(-oh // n), -(-(ph0 + h) // (n * stride)))
    bot = max(kh - stride, 0)
    if bot > ol * stride:
        return None
    return HaloPlan(n=n, aligned=False, top=0, bot=bot,
                    pad_top=ph0, pad_bot=n * ol * stride - ph0 - h,
                    oh=oh, ol=ol)


def halo_bytes(x_shape, kh: int, stride: int, padding: str, n: int,
               dtype_bytes: int = 4) -> int:
    """Wire bytes one conv's halo exchange moves per device pair — the
    analytic cross-check for the dryrun's collective-permute accounting."""
    plan = plan_halo(x_shape[1], kh, stride, padding, n)
    if plan is None or plan.n <= 1:
        return 0
    rows = plan.top + plan.bot
    return rows * x_shape[0] * x_shape[2] * x_shape[3] * dtype_bytes


def _exchange(x, plan: HaloPlan, axis: str):
    """Assemble the extended local slab: [top halo; shard; bottom halo].

    ``ppermute`` fills non-receiving edge devices with zeros — exactly the
    zero rows SAME padding (aligned path) or the sliced-off tail (general
    path) would contribute, so no edge special-casing is needed.
    """
    parts = []
    if plan.top:
        parts.append(jax.lax.ppermute(
            x[:, -plan.top:], axis,
            [(i, i + 1) for i in range(plan.n - 1)]))
    parts.append(x)
    if plan.bot:
        parts.append(jax.lax.ppermute(
            x[:, :plan.bot], axis,
            [(i + 1, i) for i in range(plan.n - 1)]))
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else x


def _prepare(x, kh: int, kw: int, stride: int, padding: str, n: int):
    """Shared pre-shard_map geometry: plan + global W (and general-path H)
    zero padding, so the per-shard kernel always runs padding='VALID'."""
    plan = plan_halo(x.shape[1], kh, stride, padding, n)
    if plan is None:
        return None, x
    (pw0, pw1), _ = conv_pads(x.shape[2], kw, stride, padding)
    x = jnp.pad(x, ((0, 0), (plan.pad_top, plan.pad_bot),
                    (pw0, pw1), (0, 0)))
    return plan, x


def _finish(out, plan: HaloPlan):
    return out if out.shape[1] == plan.oh else out[:, :plan.oh]


# ---------------------------------------------------------------------------
# trunk conv (the 'pallas_sharded' engine's conv path) + STE backward
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def sharded_trunk_conv(cfg: cim_lib.CiMConfig, stride: int, padding: str,
                       mesh, axis: str, x, w_q, w_scale):
    """H-sharded frozen-trunk convolution, bit-identical to the unsharded
    ``trunk_conv_pallas``; STE backward (dx only — the ROM cannot be
    written) via the plain XLA conv transpose, which GSPMD shards.

    mesh/axis are static: the jax Mesh and the name of its axis H is
    sharded over.  Raises when :func:`plan_halo` is infeasible — callers
    (the engine) check feasibility first and fall back unsharded.
    """
    plan, xp = _prepare(x, w_q.shape[0], w_q.shape[1], stride, padding,
                        mesh.shape[axis])
    if plan is None:
        raise ValueError(
            f"halo plan infeasible: H={x.shape[1]} kernel={w_q.shape[0]} "
            f"stride={stride} over {mesh.shape[axis]} shards (halo spans "
            f"more than one neighbour); use the unsharded engine")

    def body(xl, w_q, w_scale):
        xe = _exchange(xl, plan, axis)
        return trunk_conv_pallas(xe, w_q, w_scale, cfg,
                                 stride=stride, padding="VALID")

    spec = P(None, axis, None, None)
    out = shard_map(body, mesh=mesh, in_specs=(spec, P(), P()),
                    out_specs=spec, check_rep=False)(xp, w_q, w_scale)
    return _finish(out, plan)


def _sharded_fwd(cfg, stride, padding, mesh, axis, x, w_q, w_scale):
    out = sharded_trunk_conv(cfg, stride, padding, mesh, axis,
                             x, w_q, w_scale)
    return out, trunk_conv_residuals(x, w_q, w_scale)


def _sharded_bwd(cfg, stride, padding, mesh, axis, res, g):
    del cfg, mesh, axis
    return trunk_conv_ste_bwd(stride, padding, res, g)


sharded_trunk_conv.defvjp(_sharded_fwd, _sharded_bwd)


# ---------------------------------------------------------------------------
# fused ReBranch conv (inference fast path), same halo geometry
# ---------------------------------------------------------------------------

def sharded_rebranch_conv(x, w_q, w_scale, c, core, u,
                          cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(
                              mode="ideal"),
                          *, stride: int = 1, padding: str = "SAME",
                          mesh=None, axis: str = "data", tiling=None):
    """H-sharded fused ReBranch conv (trunk + compress sketch in one pass
    per shard).  The branch epilogue ``(t1 @ core) @ U`` is per-patch-row,
    so it shards for free with the output rows.  Trunk contribution is
    bit-identical to ``rebranch_conv_pallas``; the float branch GEMMs
    match to 1 ulp (see the module docstring).  Forward-only, like its
    unsharded twin.

    ``tiling`` (a ``repro.tune.Tiling``) pins the per-shard kernel's
    block sizes; left ``None``, each shard consults the tuning table
    keyed on its *local* patch-GEMM geometry.  Either way bit-parity is
    safe: legal tilings never change the trunk's k-partition, so a
    sharded lookup (local M) and an unsharded one (global M) landing on
    different entries still produce bit-identical trunks."""
    plan, xp = _prepare(x, w_q.shape[0], w_q.shape[1], stride, padding,
                        mesh.shape[axis])
    if plan is None:
        raise ValueError(
            f"halo plan infeasible: H={x.shape[1]} kernel={w_q.shape[0]} "
            f"stride={stride} over {mesh.shape[axis]} shards")
    bm, bn, bk = ((tiling.block_m, tiling.block_n, tiling.block_k)
                  if tiling is not None else (None, None, None))

    def body(xl, w_q, w_scale, c, core, u):
        xe = _exchange(xl, plan, axis)
        return rebranch_conv_pallas(xe, w_q, w_scale, c, core, u, cfg,
                                    stride=stride, padding="VALID",
                                    block_m=bm, block_n=bn, block_k=bk)

    spec = P(None, axis, None, None)
    out = shard_map(body, mesh=mesh,
                    in_specs=(spec, P(), P(), P(), P(), P()),
                    out_specs=spec, check_rep=False)(
                        xp, w_q, w_scale, c, core, u)
    return _finish(out, plan)
