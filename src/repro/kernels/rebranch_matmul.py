"""Pallas TPU kernel: fused ReBranch matmul (beyond-paper optimization).

The naive ReBranch layer reads the activation block twice from HBM — once
for the int8 trunk matmul and once for the branch compress projection.
This kernel fuses both: one pass over x per (m, k) block computes

  trunk[m, n] += (quant_blk(x) @ w_q) * scale_blk      (int8 MXU dot)
  t1[m, c]    += x @ C                                 (compress sketch)

with the tiny epilogue  out = trunk * w_scale + (t1 @ core) @ U  left to
XLA (it is O(M*(N+C)) — negligible).  Activation quantisation happens
in VMEM at per-(row, k-block) granularity — finer than the layer-wide
per-row scheme, so fidelity is equal or better.

Saves one full HBM read of x and the intermediate t1 round-trip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import INT8_MAX


def _rebranch_kernel(x_ref, wq_ref, c_ref, trunk_ref, t1_ref):
    n_idx, k_idx = pl.program_id(1), pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init_trunk():
        trunk_ref[...] = jnp.zeros_like(trunk_ref)

    @pl.when((k_idx == 0) & (n_idx == 0))
    def _init_t1():
        t1_ref[...] = jnp.zeros_like(t1_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, bk)

    # in-VMEM dynamic quantisation (per row, per k-block)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / INT8_MAX
    x_q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)

    acc = jax.lax.dot_general(
        x_q, wq_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    trunk_ref[...] += acc * scale

    @pl.when(n_idx == 0)
    def _compress():
        t1_ref[...] += jax.lax.dot_general(
            x, c_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def rebranch_matmul_pallas(
    x: jax.Array,          # [M, K] float
    w_q: jax.Array,        # [K, N] int8 (ROM trunk)
    w_scale: jax.Array,    # [1, N] or [N] f32
    c: jax.Array,          # [K, C] fixed compress (ROM)
    core: jax.Array,       # [C, U] trainable (SRAM)
    u: jax.Array,          # [U, N] fixed decompress (ROM)
    *,
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = x.shape
    n = w_q.shape[1]
    cdim = c.shape[1]

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    wp = jnp.pad(w_q, ((0, pad_k), (0, pad_n)))
    cp = jnp.pad(c, ((0, pad_k), (0, 0)))
    gm = xp.shape[0] // bm
    gn = wp.shape[1] // bn
    gk = xp.shape[1] // bk

    trunk, t1 = pl.pallas_call(
        _rebranch_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, cdim), lambda i, j, kk: (kk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, cdim), lambda i, j, kk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
            jax.ShapeDtypeStruct((xp.shape[0], cdim), jnp.float32),
        ],
        interpret=interpret,
    )(xp, wp, cp)

    trunk = trunk[:m, :n] * w_scale.reshape(1, -1).astype(jnp.float32)
    branch = (t1[:m] @ core.astype(jnp.float32)) @ u.astype(jnp.float32)
    return (trunk + branch).astype(x.dtype)
