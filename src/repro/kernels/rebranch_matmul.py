"""Pallas TPU kernel: fused ReBranch matmul (beyond-paper optimization).

The naive ReBranch layer reads the activation block twice from HBM — once
for the int8 trunk matmul and once for the branch compress projection.
This kernel fuses both: one pass over x per (m, k) block computes

  trunk[m, n] += macro(quant_blk(x), w_q) * scale_blk   (CiM macro dot)
  t1[m, c]    += x @ C                                  (compress sketch)

with the tiny epilogue  out = trunk * w_scale + (t1 @ core) @ U  left to
XLA (it is O(M*(N+C)) — negligible).  Activation quantisation happens
in VMEM at per-(row, k-block) granularity — finer than the layer-wide
per-row scheme, so fidelity is equal or better.  The macro dot goes
through ``cim_matmul.cim_block_dot``, so all three fidelity modes
(ideal / per_subarray / bitserial) are available, bit-compatible with
the conv kernels; K blocks are subarray-aligned for the same reason.

Saves one full HBM read of x and the intermediate t1 round-trip.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import cim as cim_lib
from repro.core.quant import quant_rows
from repro.kernels.cim_matmul import cim_block_dot
from repro.kernels.tiling import (grid_and_axes, resolve_direct,
                                  resolve_tiling)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _rebranch_kernel(cfg, n_axis, k_axis, x_ref, wq_ref, c_ref,
                     trunk_ref, t1_ref):
    n_idx, k_idx = pl.program_id(n_axis), pl.program_id(k_axis)

    @pl.when(k_idx == 0)
    def _init_trunk():
        trunk_ref[...] = jnp.zeros_like(trunk_ref)

    @pl.when((k_idx == 0) & (n_idx == 0))
    def _init_t1():
        t1_ref[...] = jnp.zeros_like(t1_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, bk)

    # in-VMEM dynamic quantisation (per row, per k-block)
    x_q, scale = quant_rows(x)
    trunk_ref[...] += cim_block_dot(cfg, x_q, wq_ref[...]) * scale

    @pl.when(n_idx == 0)
    def _compress():
        t1_ref[...] += jax.lax.dot_general(
            x, c_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


@functools.partial(jax.jit, static_argnames=("cfg", "bk"))
def _direct_rebranch(x, w_q, c, *, cfg, bk):
    """Plain-XLA lowering of the fused kernel's block decomposition.

    Same per-k-block reciprocal quantisation, macro math and ascending-K
    accumulation for trunk AND t1 as the grid kernel (see the conv twin
    in rebranch_conv.py for the exactness argument).  Jitted as its own
    compilation unit, with the multi-block accumulate under ``lax.scan``
    — a while-body fusion domain that keeps the bits caller-context-
    independent (an unrolled accumulate gets consumer-dependent FMA
    contraction once an outer jit inlines the inner jit; see
    ``_direct_trunk_patch_dot``).
    """
    m, k = x.shape
    n = w_q.shape[1]
    rows = cfg.rows_per_subarray
    gk = -(-k // bk)
    cdim = c.shape[1]

    def block(xb, wb, cb):
        """One k-block's (trunk part, t1 part) — shared by both paths."""
        absmax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
        scale = jnp.maximum(absmax, 1e-8) * (1.0 / 127.0)
        if cfg.mode == "ideal":
            q = jnp.round(xb * (1.0 / scale))
            part = (q @ wb.astype(jnp.float32)) * scale
        else:
            q = jnp.clip(jnp.round(xb * (1.0 / scale)),
                         -127.0, 127.0).astype(jnp.int8)
            part = cim_block_dot(cfg, q, wb) * scale
        return part, xb @ cb.astype(jnp.float32)

    if gk == 1:
        xb = x.astype(jnp.float32)
        if cfg.mode != "ideal":
            pad = _round_up(k, rows) - k
            trunk, t1 = block(jnp.pad(xb, ((0, 0), (0, pad))),
                              jnp.pad(w_q, ((0, pad), (0, 0))),
                              jnp.pad(c, ((0, pad), (0, 0))))
            return trunk, t1
        return block(xb, w_q, c)

    pad = gk * bk - k
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad)))
    wp = jnp.pad(w_q, ((0, pad), (0, 0)))
    cp = jnp.pad(c, ((0, pad), (0, 0)))

    def body(carry, b):
        trunk, t1 = carry
        xb = jax.lax.dynamic_slice(xp, (0, b * bk), (m, bk))
        wb = jax.lax.dynamic_slice(wp, (b * bk, 0), (bk, n))
        cb = jax.lax.dynamic_slice(cp, (b * bk, 0), (bk, cdim))
        part, t1_part = block(xb, wb, cb)
        return (trunk + part, t1 + t1_part), None

    (trunk, t1), _ = jax.lax.scan(
        body, (jnp.zeros((m, n), jnp.float32),
               jnp.zeros((m, cdim), jnp.float32)), jnp.arange(gk))
    return trunk, t1


def rebranch_matmul_pallas(
    x: jax.Array,          # [M, K] float
    w_q: jax.Array,        # [K, N] int8 (ROM trunk)
    w_scale: jax.Array,    # [1, N] or [N] f32
    c: jax.Array,          # [K, C] fixed compress (ROM)
    core: jax.Array,       # [C, U] trainable (SRAM)
    u: jax.Array,          # [U, N] fixed decompress (ROM)
    cfg: cim_lib.CiMConfig = cim_lib.CiMConfig(mode="ideal"),
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
    direct: bool | None = None,
) -> jax.Array:
    m, k = x.shape
    n = w_q.shape[1]
    cdim = c.shape[1]
    rows = cfg.rows_per_subarray

    t = resolve_tiling("rebranch_matmul", cfg.mode, str(x.dtype), m, k, n,
                       block_m=block_m, block_n=block_n, block_k=block_k,
                       defaults=(128, 256, 512), rows=rows)
    assert t.block_k % rows == 0, "K blocks must hold whole subarrays"
    bk = min(t.block_k, _round_up(k, rows))

    if resolve_direct(interpret, direct, t):
        trunk, t1 = _direct_rebranch(x, w_q, c, cfg=cfg, bk=bk)
    else:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        bm, bn = min(t.block_m, m), min(t.block_n, n)
        pad_m, pad_n, pad_k = (-m) % bm, (-n) % bn, (-k) % bk
        xp = jnp.pad(x, ((0, pad_m), (0, pad_k)))
        wp = jnp.pad(w_q, ((0, pad_k), (0, pad_n)))
        cp = jnp.pad(c, ((0, pad_k), (0, 0)))
        gm = xp.shape[0] // bm
        gn = wp.shape[1] // bn
        gk = xp.shape[1] // bk
        grid, _, n_axis, k_axis = grid_and_axes(gm, gn, gk, t.dim_order)
        if t.dim_order == "mnk":
            x_map = lambda i, j, kk: (i, kk)
            w_map = lambda i, j, kk: (kk, j)
            c_map = lambda i, j, kk: (kk, 0)
            o_map = lambda i, j, kk: (i, j)
            t1_map = lambda i, j, kk: (i, 0)
        else:
            x_map = lambda kk, i, j: (i, kk)
            w_map = lambda kk, i, j: (kk, j)
            c_map = lambda kk, i, j: (kk, 0)
            o_map = lambda kk, i, j: (i, j)
            t1_map = lambda kk, i, j: (i, 0)

        trunk, t1 = pl.pallas_call(
            functools.partial(_rebranch_kernel, cfg, n_axis, k_axis),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), x_map),
                pl.BlockSpec((bk, bn), w_map),
                pl.BlockSpec((bk, cdim), c_map),
            ],
            out_specs=[
                pl.BlockSpec((bm, bn), o_map),
                pl.BlockSpec((bm, cdim), t1_map),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]),
                                     jnp.float32),
                jax.ShapeDtypeStruct((xp.shape[0], cdim), jnp.float32),
            ],
            interpret=interpret,
        )(xp, wp, cp)
        trunk, t1 = trunk[:m, :n], t1[:m]

    trunk = trunk * w_scale.reshape(1, -1).astype(jnp.float32)
    branch = (t1 @ core.astype(jnp.float32)) @ u.astype(jnp.float32)
    return (trunk + branch).astype(x.dtype)
