"""Logical-axis sharding rules (MaxText-style) -> NamedSharding.

Models annotate activations with *logical* axes via ``shard(x, ...)``;
parameters get PartitionSpecs from their pytree path via ``param_specs``.
The mapping logical-axis -> mesh-axes is a context-scoped rule set so the
same model code runs unsharded on one CPU device and fully sharded on the
production (pod, data, model) mesh.

Divisibility: jax/GSPMD pads uneven shardings, so head counts that don't
divide the model axis (yi 56H, qwen1.5 40H) still lower — the padding
waste is surfaced by the roofline analysis instead of crashing.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> tuple of mesh axis names (tried in order, first that
# exists in the current mesh wins; missing axes mean "replicated")
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),       # data parallel over pod+data axes
    "seq": (),                      # sequence inside blocks: unsharded
    # Megatron-style sequence parallelism for the residual stream: block
    # boundaries are per-token, so the residual is sharded over the model
    # axis; GSPMD inserts all-gather at block entry (where attention needs
    # full sequence) and reduce-scatter at exit — same wire volume as the
    # all-reduces it replaces, 1/model_size the activation memory.
    "seq_sp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "embed": (),                    # residual stream replicated
    "expert": ("model",),           # EP when divisible (policy in moe.py)
    "expert_mlp": ("model",),       # per-expert hidden when EP not divisible
    "kv_seq": ("data", "model"),    # long-context cache: shard sequence
    "ssm_inner": ("model",),
    "cnn_chan": ("model",),
    # CNN serving (halo-exchange sharded conv, engine 'pallas_sharded'):
    # NHWC activations shard spatial H over the data axis; the kernel-halo
    # rows exchanged between neighbour shards inherit this same spec (a
    # halo buffer is a [N, halo_rows, W, C] slice of the activation).  W
    # is never sharded — a 2-D halo would double the exchange count for
    # no memory win at detection aspect ratios.
    "cnn_batch": ("pod",),          # image batch rides the pod axis
    "cnn_h": ("data",),             # spatial H: halo-exchange sharding
}

_state = threading.local()


def current_mesh() -> Mesh | None:
    m = getattr(_state, "mesh", None)
    if m is not None:
        return m
    # fall back to the global mesh context (`with mesh:`)
    try:
        env = jax.interpreters.pxla.thread_resources.env
        if env.physical_mesh and not env.physical_mesh.empty:
            return env.physical_mesh
    except Exception:
        pass
    return None


def current_rules() -> dict[str, tuple[str, ...]]:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    old_mesh = getattr(_state, "mesh", None)
    old_rules = getattr(_state, "rules", DEFAULT_RULES)
    _state.mesh = mesh
    _state.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        yield
    finally:
        _state.mesh = old_mesh
        _state.rules = old_rules


def mesh_axis_for(logical: str, mesh: Mesh | None = None) -> str | None:
    """The first mesh axis (rule order) a logical axis maps onto, or None.

    Unlike :func:`logical_to_spec` this returns the bare axis *name* —
    what shard_map callers (the halo-exchange conv engine) need to build
    in/out specs and ppermute over the right axis.  Axes of size 1 are
    skipped: sharding over them is a no-op and the caller should take
    its unsharded path.
    """
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    for a in current_rules().get(logical, ()):
        if a in mesh.axis_names and mesh.shape[a] > 1:
            return a
    return None


def logical_to_spec(axes: tuple[str | None, ...],
                    mesh: Mesh | None = None) -> P:
    """Translate logical axis names to a PartitionSpec for ``mesh``."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    names = set(mesh.axis_names)
    used: set[str] = set()
    parts = []
    for ax in axes:
        if ax is None or ax == "":
            parts.append(None)
            continue
        mesh_axes = tuple(a for a in current_rules().get(ax, ())
                          if a in names and a not in used)
        used.update(mesh_axes)
        if len(mesh_axes) == 0:
            parts.append(None)
        elif len(mesh_axes) == 1:
            parts.append(mesh_axes[0])
        else:
            parts.append(mesh_axes)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain activation sharding by logical axes (no-op without mesh).

    Size-aware: a dimension is only sharded if it divides evenly by the
    mesh axes assigned to it — otherwise that axis is dropped (replicated)
    instead of forcing GSPMD into padded/conflicting shardings (e.g. gemma
    kv=1 or yi 56H on a 16-way model axis)."""
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    spec = logical_to_spec(axes, mesh)
    parts = list(spec) + [None] * (x.ndim - len(spec))
    fixed = []
    for dim, part in zip(x.shape, parts):
        if part is None:
            fixed.append(None)
            continue
        names = part if isinstance(part, tuple) else (part,)
        size = int(np.prod([mesh.shape[n] for n in names]))
        fixed.append(part if dim % size == 0 and dim >= size else None)
    while fixed and fixed[-1] is None:
        fixed.pop()
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# parameter sharding from pytree paths
# ---------------------------------------------------------------------------

_WIDE_OUT = ("['q']", "['k']", "['v']", "['gate']", "['up']", "['in_proj']",
             "['x_proj']", "['dt_proj']", "['head']", "['lm_head']",
             "['shared_gate']", "['codebook_head']")
_WIDE_IN = ("['o']", "['down']", "['out_proj']")


import re as _re

_LAYER_LIST_RE = _re.compile(r"\['layers'\]\[\d+\]")


def _spec_for_param(path: str, leaf, mesh: Mesh) -> P:
    """Heuristic path->spec rules for every model family in the zoo.

    Conventions (see models/*): weights are [d_in, d_out] with the tensor-
    parallel ("wide") dim on the output side for q/k/v/gate/up/... and on
    the input side for o/down/out_proj; stacked expert weights are
    [E, d_in, d_out]; embedding tables are [V, d].  Branch compress C is
    [d_in, d_in/D] (small, replicated); core and decompress U follow the
    trunk's wide side so the branch epilogue needs no extra collective.

    Scan-over-layers archs stack per-layer params with a leading L dim
    (path has ['layers'] without an index): the rule is computed on the
    per-layer shape and L is left unsharded.
    """
    r = lambda *axes: logical_to_spec(axes, mesh)
    nd = getattr(leaf, "ndim", 0)
    stacked = ("['layers']" in path and not _LAYER_LIST_RE.search(path))
    if stacked:
        nd -= 1                            # effective per-layer ndim

    def out(spec: P) -> P:
        return P(None, *spec) if stacked else spec

    if "table_q" in path or "table_scale" in path:
        return r("vocab", None)            # embeddings are never stacked

    wide_out = any(k in path for k in _WIDE_OUT)
    wide_in = any(k in path for k in _WIDE_IN)
    is_weight = ("w_q" in path or "['w']" in path)

    if "experts" in path:
        # EP over the model axis when E divides it; otherwise TP *within*
        # each expert on its hidden dim (granite E=40, qwen2-moe E=60 on a
        # 16-way model axis take this path).
        shp = leaf.shape[1:] if stacked else leaf.shape
        m_size = mesh.shape.get("model", 1)
        ep_ok = len(shp) >= 1 and shp[0] % m_size == 0
        if nd == 3 and "w_scale" in path:            # [E, 1, d_out]
            if ep_ok:
                return out(r("expert", None, None))
            return out(P(None, None, "model")) if wide_out else out(P())
        if nd == 3:                                  # [E, d_in, d_out]
            if ep_ok:
                return out(r("expert", None, None))
            if "core" in path:
                return out(P()) if wide_out else out(P(None, "model", None))
            if wide_out:
                return out(P(None, None, "model"))
            return out(P(None, "model", None))      # down: contract dim
        if nd == 2 and "['C']" in path:              # shared compress
            return out(P()) if wide_out else out(P("model", None))
        if nd == 2 and "['U']" in path:              # shared decompress
            return out(P(None, "model")) if wide_out else out(P())
        return P()

    if nd == 2 and is_weight:
        if wide_out:
            return out(r(None, "mlp"))     # model axis on outputs
        if wide_in:
            return out(r("mlp", None))     # model axis on inputs
        return P()
    if nd == 2 and "w_scale" in path:
        if wide_out:
            return out(r(None, "mlp"))     # scales track the trunk outputs
        return P()
    # Branch tensors.  Column-parallel trunks (wide_out): C/core replicated
    # (t1 is only d_in/D wide), U sharded on outputs so t1 @ (core@U) lands
    # exactly on the trunk sharding — zero extra collectives.  Row-parallel
    # trunks (wide_in): C and core sharded on the *contracting* side so t1
    # reduce-scatters to [., d_in/D / m] and the epilogue's partial sums
    # merge into the trunk's own all-reduce.
    if nd == 2 and "['U']" in path:
        return out(r(None, "mlp")) if wide_out else P()
    if nd == 2 and "core" in path:
        return P() if wide_out else out(r("mlp", None))
    if nd == 2 and "['C']" in path:
        return P() if wide_out else out(r("mlp", None))
    return P()                             # small: replicate


def _size_check(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes whose dimension doesn't divide the mesh axes."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    fixed = []
    for dim, part in zip(shape, parts):
        if part is None:
            fixed.append(None)
            continue
        names = part if isinstance(part, tuple) else (part,)
        size = int(np.prod([mesh.shape[n] for n in names]))
        fixed.append(part if dim % size == 0 and dim >= size else None)
    while fixed and fixed[-1] is None:
        fixed.pop()
    return P(*fixed)


def param_specs(params, mesh: Mesh | None = None):
    """Pytree of PartitionSpec matching ``params``."""
    mesh = mesh or current_mesh()

    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        if mesh is None:
            return P()
        spec = _spec_for_param(p, leaf, mesh)
        return _size_check(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_specs(params, mesh),
        is_leaf=lambda s: isinstance(s, P))
