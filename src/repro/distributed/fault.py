"""Fault-tolerance coordinator logic (pure, unit-testable).

At 1000+ node scale the failure model is: hosts heartbeat to a
coordinator; the coordinator detects dead/straggling hosts, excludes
them, and emits a re-mesh plan; training resumes from the last checkpoint
on the surviving mesh (the data pipeline is stateless, so shard
reassignment is just arithmetic — see data/synthetic.py).

This module implements the *decision logic* as pure functions over a
heartbeat table.  On a real cluster it is driven by the cluster agent; in
tests it is driven directly.  jax on CPU cannot simulate host loss, so
the runtime wiring is exercised via the elastic-restore path
(checkpoint/manager.py + tests/test_fault.py).
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    heartbeat_timeout_s: float = 60.0     # dead if silent this long
    straggler_factor: float = 2.0         # step_time > factor * median
    min_data_parallel: int = 2            # refuse to shrink below this
    spare_hosts: int = 0                  # hot spares to draw from first


@dataclasses.dataclass(frozen=True)
class HostState:
    host_id: int
    last_heartbeat_s: float
    last_step_time_s: float = 0.0
    is_spare: bool = False


def dead_hosts(hosts: list[HostState], now_s: float,
               cfg: FaultConfig) -> list[int]:
    return [h.host_id for h in hosts
            if now_s - h.last_heartbeat_s > cfg.heartbeat_timeout_s]


def stragglers(hosts: list[HostState], cfg: FaultConfig) -> list[int]:
    """Hosts whose step time exceeds straggler_factor x median."""
    times = sorted(h.last_step_time_s for h in hosts
                   if h.last_step_time_s > 0)
    if len(times) < 3:
        return []
    median = times[len(times) // 2]
    return [h.host_id for h in hosts
            if h.last_step_time_s > cfg.straggler_factor * median]


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    surviving_hosts: tuple
    new_data_axis: int          # data-parallel degree after re-mesh
    replaced_by_spares: tuple   # (failed, spare) pairs
    action: str                 # 'none' | 'swap_spares' | 'shrink' | 'abort'


def plan_remesh(hosts: list[HostState], failed: list[int],
                data_axis: int, hosts_per_data_row: int,
                cfg: FaultConfig) -> RemeshPlan:
    """Decide how to continue after ``failed`` hosts drop.

    Policy (standard large-pod practice):
      1. swap in hot spares 1:1 if available (no topology change);
      2. otherwise shrink the data axis to the largest power of two that
         the surviving hosts can fill (model axis is never shrunk — the
         weights are sharded over it);
      3. abort if below min_data_parallel.
    """
    failed_set = set(failed)
    spares = [h.host_id for h in hosts
              if h.is_spare and h.host_id not in failed_set]
    alive = [h.host_id for h in hosts
             if not h.is_spare and h.host_id not in failed_set]

    if len(spares) >= len(failed):
        pairs = tuple(zip(sorted(failed), spares))
        return RemeshPlan(tuple(sorted(alive + spares[:len(failed)])),
                          data_axis, pairs, "swap_spares")

    usable_rows = len(alive) // hosts_per_data_row
    new_data = 2 ** int(math.floor(math.log2(max(usable_rows, 1))))
    if new_data < cfg.min_data_parallel:
        return RemeshPlan(tuple(alive), 0, (), "abort")
    kept = tuple(alive[:new_data * hosts_per_data_row])
    return RemeshPlan(kept, new_data, (), "shrink")


def reassign_data_shards(num_shards: int, surviving: list[int]) -> dict:
    """shard -> host map after failure; pure arithmetic (stateless data)."""
    return {s: surviving[s % len(surviving)] for s in range(num_shards)}
