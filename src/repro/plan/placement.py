"""PlacementPlan: the canonical site -> (engine, spec, residency) mapping.

A :class:`PlacementPlan` is the frozen, hashable artifact that answers
the paper's deployment question per site: which engine runs the trunk,
whether the weights are ROM-resident (frozen int8 + optional SRAM
ReBranch) or SRAM-resident (plain trainable), and under which
``ReBranchSpec``.  ``repro.deploy.compile_model(cfg, plan=...)`` consumes
it directly; the legacy ``rebranch_overrides`` tuple and the
``layer_overrides`` kwarg are thin constructors over it
(:meth:`PlacementPlan.from_config` / :meth:`PlacementPlan.build`).

Residency is encoded exactly as the models consume it: a spec with
``enabled=True`` is a ROM trunk (``'rom'``), ``enabled=False`` a plain
SRAM-trainable layer (``'sram'``).  Aggregate :class:`PlanStats` (ROM
bits, SRAM branch bits, MACs) are computed from the family's site tree
(``repro.plan.sites``) and feed the Fig. 12 cost model in
``repro.plan.solve``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core import cim as cim_lib
from repro.core.rebranch import ReBranchSpec
from repro.engine.base import TrunkEngine
from repro.plan import sites as sites_lib

OVERRIDE_KEYS = ("engine", "memory", "cim", "branch_enabled",
                 "d_ratio", "u_ratio")


def normalize_override(base: ReBranchSpec, site: str, ov) -> ReBranchSpec:
    """One override entry (dict or full spec) -> a concrete ReBranchSpec.

    Dict keys: ``engine`` (registry name or TrunkEngine), ``memory``
    ('rom'/'sram'), ``cim`` (CiMConfig or fidelity-mode string),
    ``branch_enabled``, ``d_ratio``, ``u_ratio``.
    """
    if isinstance(ov, ReBranchSpec):
        return ov
    if not isinstance(ov, dict):
        raise TypeError(
            f"override for {site!r} must be a dict or ReBranchSpec, "
            f"got {type(ov).__name__}")
    unknown = sorted(set(ov) - set(OVERRIDE_KEYS))
    if unknown:
        raise ValueError(
            f"override for {site!r}: unknown keys {unknown} "
            f"(valid: {list(OVERRIDE_KEYS)})")
    rep: dict[str, Any] = {}
    if "engine" in ov:
        rep["trunk_impl"] = (ov["engine"].name
                             if isinstance(ov["engine"], TrunkEngine)
                             else ov["engine"])
    if "memory" in ov:
        if ov["memory"] not in ("rom", "sram"):
            raise ValueError(
                f"override for {site!r}: memory must be 'rom' or "
                f"'sram', got {ov['memory']!r}")
        rep["enabled"] = ov["memory"] == "rom"
    if "cim" in ov:
        c = ov["cim"]
        rep["cim"] = (c if isinstance(c, cim_lib.CiMConfig)
                      else dataclasses.replace(base.cim, mode=c))
    for k in ("branch_enabled", "d_ratio", "u_ratio"):
        if k in ov:
            rep[k] = ov[k]
    return dataclasses.replace(base, **rep)


@dataclasses.dataclass(frozen=True)
class PlanStats:
    """Aggregates of a plan over its site tree (the Fig. 12 inputs).

    Bit counts use the deployment width (``weight_bits``, 8 by default):
    the branch trains in f32 in the JAX simulation but deploys onto 8-bit
    SRAM-CiM macros, matching the paper's 1/16-area framing.  MACs are
    per token for LM families, per inference for CNNs.
    """
    sites: int
    rom_sites: int
    sram_sites: int
    rom_bits: int               # frozen trunk + fixed C/U projections
    rom_trunk_bits: int         # frozen trunk weights only (no C/U)
    branch_bits: int            # trainable ReBranch cores (SRAM-CiM)
    sram_bits: int              # full weights of SRAM-resident sites
    rom_macs: int
    branch_macs: int
    sram_macs: int

    @property
    def total_bits(self) -> int:
        return self.rom_bits + self.branch_bits + self.sram_bits

    @property
    def weight_bits_total(self) -> int:
        """All trunk weights at deployment width (ROM- or SRAM-resident),
        branch structure excluded — the iso-capacity comparison basis,
        conserved across residency flips."""
        return self.rom_trunk_bits + self.sram_bits

    @property
    def total_macs(self) -> int:
        return self.rom_macs + self.branch_macs + self.sram_macs


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Frozen site -> (engine, ReBranchSpec, residency) mapping.

    ``entries`` hold only the sites (or ancestor prefixes) that deviate
    from ``default``; resolution is longest-prefix, mirroring
    ``models.config.spec_for``.  Hashable — safe as a jit-static value —
    and ``as_overrides()`` is exactly the ``rebranch_overrides`` tuple
    the configs carry.
    """
    model: str
    default: ReBranchSpec = dataclasses.field(default_factory=ReBranchSpec)
    entries: tuple = ()             # ((address, ReBranchSpec), ...) sorted

    # -- resolution -----------------------------------------------------
    def spec(self, site: str) -> ReBranchSpec:
        from repro.models.config import resolve_override
        return resolve_override(self.entries, site, self.default)

    def residency(self, site: str) -> str:
        return "rom" if self.spec(site).enabled else "sram"

    def engine(self, site: str) -> str:
        return self.spec(site).trunk_impl

    def as_overrides(self) -> tuple:
        return self.entries

    # -- constructors ---------------------------------------------------
    @classmethod
    def build(cls, cfg, assignments=None, *,
              default: ReBranchSpec | None = None) -> "PlacementPlan":
        """Validated plan from an {address: override} map.

        Addresses must lie inside the family's enumerated site tree
        (leaf sites or ancestor prefixes; unknown ones raise with the
        valid set).  Override values are dicts (see
        :func:`normalize_override`) or full ``ReBranchSpec`` instances.
        Duplicate addresses raise (pass a dict to guarantee uniqueness).
        """
        default = cfg.rebranch if default is None else default
        pairs = (sorted(assignments.items())
                 if isinstance(assignments, dict)
                 else list(assignments or ()))
        seen = set()
        for addr, _ in pairs:
            if addr in seen:
                raise ValueError(f"duplicate placement for site {addr!r}")
            seen.add(addr)
        tree = sites_lib.try_site_tree(cfg)
        if tree is not None and pairs:
            valid = sites_lib.valid_addresses(tree)
            unknown = sorted(seen - valid)
            if unknown:
                raise ValueError(
                    f"placement sites {unknown} are not wired for "
                    f"{cfg.name!r}; valid sites: {sorted(valid)}")
        entries = tuple(sorted(
            (addr, normalize_override(default, addr, ov))
            for addr, ov in pairs))
        return cls(model=cfg.name, default=default, entries=entries)

    @classmethod
    def from_config(cls, cfg) -> "PlacementPlan":
        """The plan a config already encodes in ``rebranch_overrides``."""
        return cls.build(cfg, tuple(getattr(cfg, "rebranch_overrides", ())))

    # -- aggregates -----------------------------------------------------
    def stats(self, cfg, weight_bits: int = 8) -> PlanStats:
        """Aggregate ROM/SRAM bits and MACs over the config's site tree."""
        tree = sites_lib.site_tree(cfg)
        rom_b = rom_tb = branch_b = sram_b = 0
        rom_m = branch_m = sram_m = 0
        n_rom = n_sram = 0
        for site in tree:
            spec = self.spec(site.name)
            if not spec.enabled:
                n_sram += 1
                sram_b += site.total_weights * weight_bits
                sram_m += site.total_macs
                continue
            n_rom += 1
            rom_b += site.total_weights * weight_bits
            rom_tb += site.total_weights * weight_bits
            rom_m += site.total_macs
            if spec.branch_enabled:
                proj_w, core_w, bmacs = site.branch_costs(spec)
                rom_b += proj_w * site.count * weight_bits
                branch_b += core_w * site.count * weight_bits
                branch_m += bmacs * site.count
        return PlanStats(sites=len(tree), rom_sites=n_rom,
                         sram_sites=n_sram, rom_bits=rom_b,
                         rom_trunk_bits=rom_tb,
                         branch_bits=branch_b, sram_bits=sram_b,
                         rom_macs=rom_m, branch_macs=branch_m,
                         sram_macs=sram_m)

    def __repr__(self):
        n_sram = sum(1 for _, s in self.entries if not s.enabled)
        return (f"<PlacementPlan {self.model!r} entries={len(self.entries)} "
                f"(sram={n_sram}) default={self.default.trunk_impl!r}>")
