"""Cost-driven ROM/SRAM placement: the Fig. 12 tradeoff as a solver.

The paper hand-picks which layers stay SRAM-trainable (first/last/small
layers) and freezes the bulk into ROM-CiM; :func:`solve` derives that
map from the cost model instead.  Area is priced per site with the
Table-I densities from ``core.energy.CostModel``:

  ROM residency : trunk (+ fixed C/U projections) at the ROM density,
                  the trainable branch core on SRAM-CiM
  SRAM residency: the full trunk at the (19x sparser) SRAM density

Every site starts ROM (the minimum-area YOLoC design point); sites then
flip to SRAM in ascending order of the extra area the flip costs until
the area budget is exhausted — small early/late layers flip first, the
bulk mid convs stay ROM, reproducing Fig. 12's qualitative shape.
:func:`sweep` walks budgets from all-ROM to all-SRAM and emits the area
map + energy ratios per point (the ``fig12`` dry-run family and the
``placement`` benchmark section are thin wrappers over it).
"""

from __future__ import annotations

import dataclasses

from repro.core.energy import DEFAULT_COST, CostModel
from repro.plan import sites as sites_lib
from repro.plan.placement import PlacementPlan, PlanStats


# ---------------------------------------------------------------------------
# pricing (CostModel-wired)
# ---------------------------------------------------------------------------

def plan_area_mm2(stats: PlanStats, cm: CostModel = DEFAULT_COST) -> float:
    """Chip area of a plan: ROM bits at ROM density, everything
    SRAM-resident (branch cores + SRAM trunks) at SRAM density."""
    return (stats.rom_bits / 1e6 / cm.rom_density_mb_mm2
            + (stats.branch_bits + stats.sram_bits) / 1e6
            / cm.sram_density_mb_mm2)


def plan_energy_mj(stats: PlanStats, cm: CostModel = DEFAULT_COST) -> float:
    """MAC energy per unit of work (inference for CNNs, token for LMs):
    ROM-resident MACs at ROM efficiency, branch + SRAM MACs at SRAM
    efficiency.  Activation-movement terms live in ``core.energy`` (they
    need the jaxpr-derived traffic, not the site tree)."""
    pj = (stats.rom_macs * cm.rom_pj_per_mac
          + (stats.branch_macs + stats.sram_macs) * cm.sram_pj_per_mac)
    return pj * 1e-9


def efficiency_vs_iso_sram(stats: PlanStats,
                           cm: CostModel = DEFAULT_COST,
                           reload_factor: float = 1.0) -> float:
    """Energy ratio of the iso-area all-SRAM-CiM chip over this plan
    (the Fig. 13(b)-style comparison, MAC + weight-reload terms).

    The baseline chip gets the plan's area in SRAM-CiM; trunk weights
    beyond its capacity stream from DRAM ``reload_factor`` times per
    unit of work.
    """
    area = plan_area_mm2(stats, cm)
    capacity_bits = area * cm.sram_density_mb_mm2 * 1e6
    reload_bits = max(0.0, stats.weight_bits_total - capacity_bits)
    base_pj = (stats.total_macs * cm.sram_pj_per_mac
               + reload_bits * reload_factor * cm.dram_pj_per_bit)
    ours_pj = plan_energy_mj(stats, cm) * 1e9
    return base_pj / max(ours_pj, 1e-30)


# ---------------------------------------------------------------------------
# the greedy solver
# ---------------------------------------------------------------------------

def _site_areas(site: sites_lib.Site, spec, cm: CostModel,
                weight_bits: int = 8):
    """(rom_area, sram_area) in mm^2 for one site under ``spec`` — the
    same ``Site.branch_costs`` accounting PlacementPlan.stats uses, so
    the greedy pricing can never drift from the reported stats."""
    w_bits = site.total_weights * weight_bits
    rom_bits, branch_bits = w_bits, 0
    if spec.branch_enabled:
        proj_w, core_w, _ = site.branch_costs(spec)
        rom_bits += proj_w * site.count * weight_bits
        branch_bits += core_w * site.count * weight_bits
    rom_area = (rom_bits / 1e6 / cm.rom_density_mb_mm2
                + branch_bits / 1e6 / cm.sram_density_mb_mm2)
    sram_area = w_bits / 1e6 / cm.sram_density_mb_mm2
    return rom_area, sram_area


def solve(cfg, budget_mm2: float | None = None, *,
          cm: CostModel = DEFAULT_COST, engine: str | None = None,
          weight_bits: int = 8) -> PlacementPlan:
    """Greedy cost-driven ROM/SRAM residency under an area budget.

    Starts from the minimum-area deployment — every site a ROM trunk
    with its SRAM ReBranch (the YOLoC design point) — and spends the
    remaining budget flipping sites to full SRAM residency (plain
    trainable layers), cheapest area delta first.  With the Table-I
    densities the delta is ~proportional to a site's weight count, so
    the small early/late layers flip first and the bulk mid layers stay
    ROM: the paper's Fig. 12 shape.

    budget_mm2: total chip area.  ``None`` or anything at/below the
        all-ROM area returns the all-ROM plan (you cannot buy less area
        than the densest mapping); at/above the all-SRAM area every site
        flips.
    engine: optional trunk-engine name for the plan's default spec.
    Returns a :class:`PlacementPlan` — feed it straight to
    ``repro.deploy.compile_model(cfg, plan=...)``.
    """
    default = cfg.rebranch
    if engine is not None:
        default = dataclasses.replace(default, trunk_impl=engine)
    tree = sites_lib.site_tree(cfg)
    priced = []
    base_area = 0.0
    for site in tree:
        rom_a, sram_a = _site_areas(site, default, cm, weight_bits)
        base_area += rom_a
        priced.append((sram_a - rom_a, site))
    spend = (budget_mm2 - base_area) if budget_mm2 is not None else 0.0

    assignments = {}
    sram_spec = dataclasses.replace(default, enabled=False)
    for delta, site in sorted(priced, key=lambda p: (p[0], p[1].name)):
        if delta > spend:
            break
        spend -= delta
        assignments[site.name] = sram_spec
    return PlacementPlan.build(cfg, assignments, default=default)


def sweep(cfg, n_points: int = 8, *, cm: CostModel = DEFAULT_COST,
          engine: str | None = None, reload_factor: float = 1.0) -> list:
    """Walk area budgets from all-ROM to all-SRAM; one record per point.

    Records carry the budget, the solved plan, its stats and the priced
    outputs (area, MAC energy, iso-area-SRAM efficiency ratio, SRAM site
    names) — the Fig. 12 area map as data.
    """
    all_rom = solve(cfg, None, cm=cm, engine=engine)
    lo = plan_area_mm2(all_rom.stats(cfg), cm)
    tree = sites_lib.site_tree(cfg)
    hi = sum(_site_areas(s, all_rom.default, cm)[1] for s in tree)
    out = []
    for i in range(n_points):
        budget = lo + (hi - lo) * i / max(1, n_points - 1)
        plan = solve(cfg, budget, cm=cm, engine=engine)
        stats = plan.stats(cfg)
        out.append({
            "model": cfg.name,
            "budget_mm2": round(budget, 3),
            "area_mm2": round(plan_area_mm2(stats, cm), 3),
            "energy_mj": plan_energy_mj(stats, cm),
            "efficiency_x": round(
                efficiency_vs_iso_sram(stats, cm, reload_factor), 3),
            "rom_sites": stats.rom_sites,
            "sram_sites": stats.sram_sites,
            "sram_site_names": [s for s, sp in plan.entries
                                if not sp.enabled],
            "plan": plan,
        })
    return out
