"""ROM/SRAM placement subsystem (paper §4, Fig. 12).

The paper's central deployment question — which weights live in dense
ROM-CiM and which stay SRAM-trainable (with or without a ReBranch) —
becomes a first-class, searchable artifact here instead of hand-written
override tuples:

  * :mod:`repro.plan.sites`     — every model family exports an
    enumerable, validated site tree (named parameter groups with shapes,
    weight and MAC counts).
  * :mod:`repro.plan.placement` — :class:`PlacementPlan`, the frozen
    site -> (engine, ReBranchSpec, ROM/SRAM residency) mapping that
    ``repro.deploy.compile_model(cfg, plan=...)`` consumes, with
    aggregate ROM/SRAM-bit and MAC stats.
  * :mod:`repro.plan.solve`     — the cost-driven planner: greedy
    ROM-vs-SRAM residency per site under an area budget using
    ``core.energy.CostModel``, reproducing the Fig. 12 tradeoff curve.
"""

from repro.plan.placement import (PlacementPlan, PlanStats,  # noqa: F401
                                  normalize_override)
from repro.plan.sites import (Site, site_tree, try_site_tree,  # noqa: F401
                              valid_addresses)
from repro.plan.solve import (plan_area_mm2, plan_energy_mj,  # noqa: F401
                              efficiency_vs_iso_sram, solve, sweep)
