"""The site protocol: every model family's enumerable parameter-group tree.

A *site* is a named group of trunk weights that one ``ReBranchSpec``
governs — the unit at which the paper maps layers onto ROM-CiM vs
SRAM-CiM (Fig. 12).  Site names are dotted paths; model code resolves
them at trace time through ``models.config.spec_for`` (longest-prefix
match, so an override at ``'blocks'`` governs ``'blocks.attn'``,
``'blocks.ssm.in_proj'``, ...).

This module is the ONE enumeration the rest of the system validates
against: :class:`repro.plan.PlacementPlan` and
``repro.deploy.compile_model`` reject addresses outside
:func:`valid_addresses`, and the cost-driven planner (``plan.solve``)
prices each site from the shapes/MAC counts recorded here.

Site trees per family (leaf sites; ancestors are valid override
addresses too):

  transformer (dense/vlm/audio) : blocks.attn, blocks.mlp, lm_head |
                                  codebook_head (untied readouts only)
  moe                           : blocks.attn, blocks.moe, lm_head
  ssm (mamba)                   : blocks.{in,x,dt,out}_proj, lm_head
  hybrid (hymba)                : blocks.attn, blocks.ssm.{...}_proj,
                                  blocks.mlp, lm_head
  cnn (vgg8/resnet18/darknet19/tiny_yolo): the conv sites enumerated by
      ``models.cnn.conv_site_shapes`` ('stem', 'convs.N',
      'stages.S.B.convK', 'head.N')

Small always-SRAM parameters (norms, biases, routers, BN, the YOLO 1x1
predictor) and the always-ROM embedding table are not sites: they never
move between substrates.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Site:
    """One named trunk parameter group of a model's site tree.

    weights : trunk weight scalars per occurrence.
    macs    : trunk MACs per unit of work per occurrence — per TOKEN for
              LM families, per INFERENCE for CNNs (matching what the
              Fig. 12-14 cost model consumes for each).
    count   : identical occurrences sharing this site (scan-stacked
              layers); totals multiply it in.
    shape   : representative weight shape — (d_in, d_out) for matmul
              sites, (k, k, c_in, c_out) for convs; composite sites
              (several projections under one name) record their members
              in ``members`` instead.
    """
    name: str
    kind: str                       # 'matmul' | 'conv'
    weights: int
    macs: int
    count: int = 1
    shape: tuple = ()
    members: tuple = ()             # ((label, (d_in, d_out)), ...)
    # ReBranch accounting members: ((d_in, d_out, core_rep, core_active),
    # ...) per occurrence — core_rep replicas of the trainable core share
    # ONE fixed C/U pair (stacked MoE experts: rep=E), of which
    # core_active run per unit of work (top-k routing).  None -> derived
    # from ``members``/``shape`` with rep = active = 1.
    branch_members: tuple | None = None

    @property
    def total_weights(self) -> int:
        return self.weights * self.count

    @property
    def total_macs(self) -> int:
        return self.macs * self.count

    def branch_costs(self, spec) -> tuple:
        """(rom_proj_weights, core_weights, branch_macs) per occurrence —
        the ONE home of ReBranch cost accounting (PlacementPlan.stats and
        the solver's area pricing both consume it).  Mirrors
        core.rebranch.init_linear / models.cnn.init_conv /
        models.moe.init_expert_linear: C/U projections are fixed (ROM),
        the core is the trainable SRAM tensor.  branch_macs are per the
        site's MAC unit (token / inference)."""
        if self.kind == "conv":
            k, _, c_in, c_out = self.shape
            c_c = max(1, c_in // spec.d_ratio)
            c_u = max(1, c_out // spec.u_ratio)
            reuse = self.macs / max(1, self.weights)   # spatial positions
            proj = c_in * c_c + c_u * c_out
            core = k * k * c_c * c_u
            return proj, core, int((proj + k * k * c_c * c_u) * reuse)
        bm = self.branch_members
        if bm is None:
            bm = tuple((a, b, 1, 1)
                       for _, (a, b) in (self.members or
                                         (("w", self.shape),)))
        proj = core = bmacs = 0
        for d_in, d_out, rep, active in bm:
            d_c = max(1, d_in // spec.d_ratio)
            d_u = max(1, d_out // spec.u_ratio)
            proj += d_in * d_c + d_u * d_out
            core += d_c * d_u * rep
            bmacs += (d_in * d_c + d_c * d_u + d_u * d_out) * active
        return proj, core, bmacs


def _matmul_site(name: str, members, count: int = 1) -> Site:
    """Composite matmul site: members are (label, (d_in, d_out)) pairs.
    Matmul MACs per token = weight count (one MAC per weight)."""
    members = tuple((lbl, tuple(shape)) for lbl, shape in members)
    w = sum(a * b for _, (a, b) in members)
    single = members[0][1] if len(members) == 1 else ()
    return Site(name=name, kind="matmul", weights=w, macs=w, count=count,
                shape=single, members=members)


# ---------------------------------------------------------------------------
# per-family site trees
# ---------------------------------------------------------------------------

def _attn_members(cfg):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return [("q", (d, h * dh)), ("k", (d, kv * dh)),
            ("v", (d, kv * dh)), ("o", (h * dh, d))]


def _mlp_members(cfg, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_type in ("swiglu", "geglu"):
        return [("gate", (d, ff)), ("up", (d, ff)), ("down", (ff, d))]
    return [("up", (d, ff)), ("down", (ff, d))]


def _head_sites(cfg):
    if cfg.num_codebooks:
        return [_matmul_site("codebook_head",
                             [("w", (cfg.d_model,
                                     cfg.num_codebooks * cfg.vocab_size))])]
    if cfg.tie_embeddings:
        return []                   # readout reuses the ROM embedding table
    return [_matmul_site("lm_head", [("w", (cfg.d_model, cfg.vocab_size))])]


def _moe_site(cfg) -> Site:
    """Stacked ReBranch experts: weights cover all E experts; MACs per
    token only the top-k active ones (plus the always-on shared expert).
    The experts share ONE C/U sketch pair per stack with a per-expert
    core (models.moe.init_expert_linear), recorded in branch_members as
    (d_in, d_out, rep=E, active=k)."""
    d, ff, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    k = cfg.num_experts_per_tok
    members = [("gate", (d, ff)), ("up", (d, ff)), ("down", (ff, d))]
    w_expert = sum(a * b for _, (a, b) in members)
    weights, macs = e * w_expert, k * w_expert
    all_members = [(f"experts.{lbl}", (e * a, b)) for lbl, (a, b) in members]
    branch = [(a, b, e, k) for _, (a, b) in members]
    if cfg.num_shared_experts:
        shared_ff = cfg.num_shared_experts * ff
        shared = _mlp_members(cfg, d_ff=shared_ff)
        w_shared = sum(a * b for _, (a, b) in shared)
        weights += w_shared
        macs += w_shared
        all_members += [(f"shared.{lbl}", shape) for lbl, shape in shared]
        branch += [(a, b, 1, 1) for _, (a, b) in shared]
    return Site(name="blocks.moe", kind="matmul", weights=weights,
                macs=macs, count=cfg.num_layers,
                members=tuple((lbl, tuple(s)) for lbl, s in all_members),
                branch_members=tuple(branch))


def _ssm_proj_sites(cfg, prefix: str) -> list:
    d, di, n, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    return [
        _matmul_site(f"{prefix}.in_proj", [("w", (d, 2 * di))],
                     count=cfg.num_layers),
        _matmul_site(f"{prefix}.x_proj", [("w", (di, dtr + 2 * n))],
                     count=cfg.num_layers),
        _matmul_site(f"{prefix}.dt_proj", [("w", (dtr, di))],
                     count=cfg.num_layers),
        _matmul_site(f"{prefix}.out_proj", [("w", (di, d))],
                     count=cfg.num_layers),
    ]


def _arch_sites(cfg) -> list:
    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        return [_matmul_site("blocks.attn", _attn_members(cfg),
                             count=cfg.num_layers),
                _matmul_site("blocks.mlp", _mlp_members(cfg),
                             count=cfg.num_layers)] + _head_sites(cfg)
    if fam == "moe":
        return [_matmul_site("blocks.attn", _attn_members(cfg),
                             count=cfg.num_layers),
                _moe_site(cfg)] + _head_sites(cfg)
    # ssm/hybrid init always build a real lm_head ReBranch group (their
    # families ignore tie_embeddings/num_codebooks), so the site is
    # unconditional — _head_sites applies transformer-family rules only
    lm_head = _matmul_site("lm_head", [("w", (cfg.d_model,
                                              cfg.vocab_size))])
    if fam == "ssm":
        return _ssm_proj_sites(cfg, "blocks") + [lm_head]
    if fam == "hybrid":
        return ([_matmul_site("blocks.attn", _attn_members(cfg),
                              count=cfg.num_layers)]
                + _ssm_proj_sites(cfg, "blocks.ssm")
                + [_matmul_site("blocks.mlp", _mlp_members(cfg),
                                count=cfg.num_layers)]
                + [lm_head])
    raise ValueError(f"no site tree for model family {fam!r}")


def _cnn_sites(cfg) -> list | None:
    from repro.models import cnn
    shapes = cnn.conv_site_shapes(cfg)
    if shapes is None:
        return None
    return [Site(name=site, kind="conv", weights=k * k * c_in * c_out,
                 macs=hw * hw * k * k * c_in * c_out,
                 shape=(k, k, c_in, c_out))
            for site, k, c_in, c_out, hw, _stride in shapes]


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def site_tree(cfg) -> tuple:
    """The enumerated, ordered site tree of ``cfg`` (tuple of Site).

    Raises for configs whose sites cannot be enumerated (unknown family,
    or a CNN name registered outside models.cnn.MODEL_REGISTRY) — use
    :func:`try_site_tree` when unconstrained configs are acceptable.
    """
    from repro.models import cnn
    if isinstance(cfg, cnn.CNNConfig):
        sites = _cnn_sites(cfg)
        if sites is None:
            raise ValueError(
                f"cannot enumerate sites for CNN {cfg.name!r}: not in "
                f"models.cnn.MODEL_REGISTRY")
        tree = tuple(sites)
    else:
        tree = tuple(_arch_sites(cfg))
    names = [s.name for s in tree]
    dup = {n for n in names if names.count(n) > 1}
    if dup:                         # a builder bug, catch it loudly
        raise ValueError(f"duplicate sites in {cfg.name!r} tree: "
                         f"{sorted(dup)}")
    return tree


def try_site_tree(cfg):
    """site_tree, or None when the config's sites cannot be enumerated."""
    try:
        return site_tree(cfg)
    except ValueError:
        return None


def valid_addresses(tree) -> set:
    """Every address an override may use: leaf site names plus all their
    dotted ancestor prefixes ('blocks' governs every 'blocks.*' site,
    'stages.1' a whole ResNet stage)."""
    out = set()
    for site in tree:
        parts = site.name.split(".")
        for i in range(1, len(parts) + 1):
            out.add(".".join(parts[:i]))
    return out


def sites_under(tree, address: str) -> tuple:
    """The leaf sites an override address governs (exact or prefix)."""
    return tuple(s for s in tree
                 if s.name == address or s.name.startswith(address + "."))
