"""Checkpoint manager: atomic, keep-k, async, mesh-agnostic, ROM-aware.

Design for 1000+ nodes (see DESIGN.md §6):

* Only the SRAM (trainable) state + optimizer + step + data cursor + PRNG
  are persisted.  The ROM trunk is immutable — the checkpoint stores only
  its fingerprint, and restore() validates it against the booted ROM
  image.  With D*U=16 this cuts checkpoint volume ~16x vs full-model
  checkpoints: at 67B-param scale, ~4 GB instead of ~130+ GB per save.
* Atomicity: write to <dir>.tmp, fsync, rename.  A crash mid-save never
  corrupts the latest-good checkpoint.
* Async: save() can run on a background thread (snapshot taken
  synchronously via device_get, IO overlapped with the next train steps).
* Mesh-agnostic: arrays are stored as full (unsharded) numpy arrays with
  their tree paths; restore(mesh) re-shards to whatever mesh is alive —
  elastic restarts with a different device count just work.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

from repro.core import rom


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): l for p, l in leaves if l is not None}


def save(ckpt_dir: str, step: int, trainable, opt_state, params_full,
         *, extra: dict | None = None, keep: int = 3,
         async_: bool = False) -> threading.Thread | None:
    """Persist SRAM state atomically; returns the IO thread if async."""
    fingerprint = rom.rom_fingerprint(params_full)
    # snapshot on the caller thread (cheap: branch-only state)
    arrays = {f"t/{k}": np.asarray(jax.device_get(v))
              for k, v in _flatten(trainable).items()}
    arrays.update({f"o/{k}": np.asarray(jax.device_get(v))
                   for k, v in _flatten(opt_state).items()})
    meta = {"step": int(step), "rom_fingerprint": fingerprint,
            "extra": extra or {}}

    def _write():
        path = os.path.join(ckpt_dir, f"step_{int(step):08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "state.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    # keep <= 0 means keep NOTHING: steps[:-0] slices to [] and would
    # silently keep everything instead
    drop = steps if keep <= 0 else steps[:-keep]
    for s in drop:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


_STEP_RE = re.compile(r"^step_(\d+)$")


def latest_steps(ckpt_dir: str) -> list[int]:
    """Step numbers of the completed checkpoints under ``ckpt_dir``.

    Only exact ``step_<int>`` names count: stray directories (an
    interrupted write renamed by hand, ``step_5_backup``, editor
    droppings) are skipped instead of crashing every restore/gc with a
    ``ValueError`` for the whole directory.
    """
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def restore(ckpt_dir: str, trainable_template, opt_template, params_full,
            *, step: int | None = None, shardings=None):
    """Load the latest (or given) step; validates the ROM fingerprint and
    re-shards onto ``shardings`` (elastic restore) if given.

    Returns (step, trainable, opt_state, extra).
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = step if step is not None else steps[-1]
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    booted = rom.rom_fingerprint(params_full)
    if meta["rom_fingerprint"] != booted:
        raise ValueError(
            "ROM fingerprint mismatch: checkpoint was trained against a "
            f"different ROM image ({meta['rom_fingerprint'][:12]} != "
            f"{booted[:12]}). Refusing to restore.")
    data = np.load(os.path.join(path, "state.npz"))

    def rebuild(template, prefix, shard_tree=None):
        isnone = lambda x: x is None
        flat_paths = jax.tree_util.tree_flatten_with_path(
            template, is_leaf=isnone)[0]
        shard_flat = (jax.tree_util.tree_flatten_with_path(
            shard_tree, is_leaf=isnone)[0]
            if shard_tree is not None else None)
        leaves = []
        for i, (p, leaf) in enumerate(flat_paths):
            if leaf is None:
                leaves.append(None)
                continue
            arr = data[f"{prefix}/{jax.tree_util.keystr(p)}"]
            if shard_flat is not None and shard_flat[i][1] is not None:
                arr = jax.device_put(arr, shard_flat[i][1])
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(
            template, is_leaf=lambda x: x is None)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    t_shard = o_shard = None
    if shardings is not None:
        t_shard, o_shard = shardings
    trainable = rebuild(trainable_template, "t", t_shard)
    opt_state = rebuild(opt_template, "o", o_shard)
    return meta["step"], trainable, opt_state, meta.get("extra", {})
