"""Checkpoint manager: atomic, keep-k, async, mesh-agnostic, ROM-aware.

Design for 1000+ nodes (see DESIGN.md §6):

* Only the SRAM (trainable) state + optimizer + step + data cursor + PRNG
  are persisted.  The ROM trunk is immutable — the checkpoint stores only
  its fingerprint, and restore() validates it against the booted ROM
  image.  With D*U=16 this cuts checkpoint volume ~16x vs full-model
  checkpoints: at 67B-param scale, ~4 GB instead of ~130+ GB per save.
* Atomicity: write to <dir>.tmp, fsync, rename.  A crash mid-save never
  corrupts the latest-good checkpoint.
* Async: save() can run on a background thread (snapshot taken
  synchronously via device_get, IO overlapped with the next train steps).
* Mesh-agnostic: arrays are stored as full (unsharded) numpy arrays with
  their tree paths; restore(mesh) re-shards to whatever mesh is alive —
  elastic restarts with a different device count just work.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

from repro.core import rom


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): l for p, l in leaves if l is not None}


def save(ckpt_dir: str, step: int, trainable, opt_state, params_full,
         *, extra: dict | None = None, keep: int = 3,
         async_: bool = False) -> threading.Thread | None:
    """Persist SRAM state atomically; returns the IO thread if async."""
    fingerprint = rom.rom_fingerprint(params_full)
    # snapshot on the caller thread (cheap: branch-only state)
    arrays = {f"t/{k}": np.asarray(jax.device_get(v))
              for k, v in _flatten(trainable).items()}
    arrays.update({f"o/{k}": np.asarray(jax.device_get(v))
                   for k, v in _flatten(opt_state).items()})
    meta = {"step": int(step), "rom_fingerprint": fingerprint,
            "extra": extra or {}}

    def _write():
        path = os.path.join(ckpt_dir, f"step_{int(step):08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "state.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _check_structure(data, expected: dict, prefix: str, *, what: str):
    """Template paths vs stored arrays under ``prefix`` — raise a
    geometry-style error naming both structures (mirrors the serve
    layer's cache_geometry errors) instead of a raw KeyError/treedef
    failure deep inside unflatten."""
    found = {k[len(prefix) + 1:] for k in data.files
             if k.startswith(prefix + "/")}
    missing = set(expected) - found
    unexpected = found - set(expected)
    if missing or unexpected:
        def prev(names, n=4):
            names = sorted(names)
            return (", ".join(names[:n])
                    + (f", ... ({len(names) - n} more)"
                       if len(names) > n else ""))
        parts = []
        if missing:
            parts.append(f"missing from checkpoint: {prev(missing)}")
        if unexpected:
            parts.append(f"not in template: {prev(unexpected)}")
        raise ValueError(
            f"{what}: checkpoint state does not match the template "
            f"({'; '.join(parts)}; template expects {len(expected)} "
            f"arrays, checkpoint holds {len(found)}) — was this "
            f"checkpoint written for a different model config or "
            f"placement plan?")
    for name, leaf in expected.items():
        if leaf is None or not hasattr(leaf, "shape"):
            continue
        got = data[f"{prefix}/{name}"].shape
        if tuple(got) != tuple(leaf.shape):
            raise ValueError(
                f"{what}: array {name} has shape {tuple(got)} in the "
                f"checkpoint but the template expects "
                f"{tuple(leaf.shape)} — geometry changed since save")


def _rebuild(data, template, prefix: str, shard_tree=None, *,
             what: str = "restore"):
    """Template tree + stored arrays -> restored tree (structure-checked,
    optionally re-sharded leaf by leaf)."""
    isnone = lambda x: x is None
    flat_paths = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=isnone)[0]
    _check_structure(
        data, {jax.tree_util.keystr(p): leaf
               for p, leaf in flat_paths if leaf is not None},
        prefix, what=what)
    shard_flat = (jax.tree_util.tree_flatten_with_path(
        shard_tree, is_leaf=isnone)[0]
        if shard_tree is not None else None)
    leaves = []
    for i, (p, leaf) in enumerate(flat_paths):
        if leaf is None:
            leaves.append(None)
            continue
        arr = data[f"{prefix}/{jax.tree_util.keystr(p)}"]
        if shard_flat is not None and shard_flat[i][1] is not None:
            arr = jax.device_put(arr, shard_flat[i][1])
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template, is_leaf=isnone)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    # keep <= 0 means keep NOTHING: steps[:-0] slices to [] and would
    # silently keep everything instead
    drop = steps if keep <= 0 else steps[:-keep]
    for s in drop:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


_STEP_RE = re.compile(r"^step_(\d+)$")


def latest_steps(ckpt_dir: str) -> list[int]:
    """Step numbers of the completed checkpoints under ``ckpt_dir``.

    Only exact ``step_<int>`` names count: stray directories (an
    interrupted write renamed by hand, ``step_5_backup``, editor
    droppings) are skipped instead of crashing every restore/gc with a
    ``ValueError`` for the whole directory.
    """
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def restore(ckpt_dir: str, trainable_template, opt_template, params_full,
            *, step: int | None = None, shardings=None):
    """Load the latest (or given) step; validates the ROM fingerprint and
    re-shards onto ``shardings`` (elastic restore) if given.

    Returns (step, trainable, opt_state, extra).
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = step if step is not None else steps[-1]
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    booted = rom.rom_fingerprint(params_full)
    if meta["rom_fingerprint"] != booted:
        raise ValueError(
            "ROM fingerprint mismatch: checkpoint was trained against a "
            f"different ROM image ({meta['rom_fingerprint'][:12]} != "
            f"{booted[:12]}). Refusing to restore.")
    data = np.load(os.path.join(path, "state.npz"))
    t_shard = o_shard = None
    if shardings is not None:
        t_shard, o_shard = shardings
    trainable = _rebuild(data, trainable_template, "t", t_shard,
                         what="restore(trainable)")
    opt_state = _rebuild(data, opt_template, "o", o_shard,
                         what="restore(opt_state)")
    return meta["step"], trainable, opt_state, meta.get("extra", {})


# ---------------------------------------------------------------------------
# branch-only checkpoints: one scenario's swappable SRAM state
# ---------------------------------------------------------------------------

_SCENARIO_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")


def _branch_path(ckpt_dir: str, scenario: str) -> str:
    if not _SCENARIO_RE.match(scenario):
        raise ValueError(
            f"scenario name {scenario!r} is not filesystem-safe "
            f"(want [A-Za-z0-9][A-Za-z0-9._-]*)")
    return os.path.join(ckpt_dir, f"branch_{scenario}")


def save_branch(ckpt_dir: str, scenario: str, branch, *,
                model_name: str, plan=None,
                extra: dict | None = None) -> None:
    """Persist ONE scenario's branch tree (the swappable SRAM state).

    The manifest names the placement-plan fingerprint the branch was
    trained under, so :func:`restore_branch` can never implant it onto
    a mismatched placement (a ROM<->SRAM flip changes which tensors the
    branch even holds).  Atomic like :func:`save`: tmp + fsync + rename.
    """
    from repro.scenario import branch as branch_lib
    path = _branch_path(ckpt_dir, scenario)
    arrays = {f"b/{k}": np.asarray(jax.device_get(v))
              for k, v in _flatten(branch).items()}
    manifest = {"scenario": scenario, "model": model_name,
                "plan_fingerprint": branch_lib.plan_fingerprint(plan),
                "extra": extra or {}}
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "state.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def branch_scenarios(ckpt_dir: str) -> list[str]:
    """Scenario names with a completed branch checkpoint under dir."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(n[len("branch_"):] for n in os.listdir(ckpt_dir)
                  if n.startswith("branch_") and not n.endswith(".tmp")
                  and os.path.isfile(os.path.join(ckpt_dir, n,
                                                  "manifest.json")))


def restore_branch(ckpt_dir: str, scenario: str, template, *,
                   plan=None, model_name: str | None = None):
    """Load one scenario's branch; refuses a plan-fingerprint mismatch.

    template: the branch tree skeleton (arrays or ShapeDtypeStructs,
    trunk positions None) the stored state must match — structure and
    shape mismatches raise the same geometry-style error as
    :func:`restore`.
    """
    from repro.scenario import branch as branch_lib
    path = _branch_path(ckpt_dir, scenario)
    if not os.path.isdir(path):
        raise FileNotFoundError(
            f"no branch checkpoint for scenario {scenario!r} under "
            f"{ckpt_dir} (have: {branch_scenarios(ckpt_dir)})")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    want_fp = branch_lib.plan_fingerprint(plan)
    if manifest["plan_fingerprint"] != want_fp:
        raise ValueError(
            f"restore_branch({scenario!r}): branch was saved under "
            f"placement plan {manifest['plan_fingerprint']} but this "
            f"deployment runs plan {want_fp}; refusing to restore a "
            f"branch onto a mismatched placement")
    if model_name is not None and manifest["model"] != model_name:
        raise ValueError(
            f"restore_branch({scenario!r}): branch was saved for model "
            f"{manifest['model']!r}, not {model_name!r}")
    data = np.load(os.path.join(path, "state.npz"))
    return _rebuild(data, template, "b",
                    what=f"restore_branch({scenario!r})")
