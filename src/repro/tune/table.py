"""Checked-in kernel tuning table: load / lookup / save.

The autotuner (:mod:`repro.tune.autotune`) measures candidate tilings per
(kernel, fidelity mode, dtype, GEMM geometry) and writes the winners to a
JSON table.  The kernels consult :func:`lookup` whenever the caller leaves
the tiling unspecified, so a checked-in ``tuning_table.json`` next to this
module transparently accelerates every conv/matmul site without touching
call sites.

Only *bit-identical* tilings are legal table entries: a tiling may change
how fast a kernel runs, never what it returns.  The autotuner enforces
that at generation time and the kernels re-check the k-partition
defensively at lookup time (see ``repro.kernels.tiling``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
from typing import Iterator, Mapping

DIM_ORDERS = ("mnk", "kmn")
IMPLS = ("grid", "direct")

_DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "tuning_table.json")


@dataclasses.dataclass(frozen=True)
class Tiling:
    """One tuned kernel configuration.

    ``dim_order`` picks the grid iteration order: ``"mnk"`` keeps K
    innermost (the historical layout), ``"kmn"`` hoists K outermost.
    Either way each (i, j) output tile still visits its K blocks in
    ascending order, so accumulation order — and hence the bits — are
    unchanged.  ``impl`` selects the execution path: ``"grid"`` is the
    ``pallas_call`` kernel, ``"direct"`` is the plain-XLA lowering that
    replicates the same block decomposition (the fast path off-TPU,
    where ``pallas_call`` runs in interpret mode).
    """

    block_m: int
    block_n: int
    block_k: int
    dim_order: str = "mnk"
    impl: str = "grid"

    def __post_init__(self):
        if self.dim_order not in DIM_ORDERS:
            raise ValueError(f"dim_order must be one of {DIM_ORDERS}, "
                             f"got {self.dim_order!r}")
        if self.impl not in IMPLS:
            raise ValueError(f"impl must be one of {IMPLS}, "
                             f"got {self.impl!r}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Mapping) -> "Tiling":
        return cls(block_m=int(d["block_m"]), block_n=int(d["block_n"]),
                   block_k=int(d["block_k"]),
                   dim_order=str(d.get("dim_order", "mnk")),
                   impl=str(d.get("impl", "grid")))


def key(kernel: str, mode: str, dtype: str, m: int, k: int, n: int) -> str:
    """Canonical table key for one kernel geometry."""
    return f"{kernel}|{mode}|{dtype}|{m}x{k}x{n}"


# ---------------------------------------------------------------------------
# Table state.  ``_stack`` holds context overrides; the base table is loaded
# lazily from the checked-in JSON and cached.
# ---------------------------------------------------------------------------

_cache: dict | None = None
_cache_path: str | None = None
_stack: list[dict[str, Tiling] | None] = []   # None == lookups disabled


def load_table(path: str | None = None) -> dict[str, Tiling]:
    """Load (and cache) the tuning table.  Missing file -> empty table."""
    global _cache, _cache_path
    p = path or _DEFAULT_PATH
    if _cache is not None and _cache_path == p:
        return _cache
    entries: dict[str, Tiling] = {}
    if os.path.exists(p):
        with open(p) as f:
            raw = json.load(f)
        for k, v in raw.get("entries", {}).items():
            entries[k] = Tiling.from_json(v)
    _cache, _cache_path = entries, p
    return entries


def save_table(entries: Mapping[str, Tiling], path: str,
               meta: Mapping | None = None) -> None:
    """Write a tuning table as deterministic (sorted-key) JSON."""
    doc = {"meta": dict(meta or {}),
           "entries": {k: entries[k].to_json() for k in sorted(entries)}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def invalidate_cache() -> None:
    global _cache, _cache_path
    _cache, _cache_path = None, None


def lookup(kernel: str, mode: str, dtype: str,
           m: int, k: int, n: int) -> Tiling | None:
    """Look up a tuned tiling; ``None`` means use the kernel default."""
    if _stack:
        top = _stack[-1]
        if top is None:          # disabled() context
            return None
        return top.get(key(kernel, mode, dtype, m, k, n))
    return load_table().get(key(kernel, mode, dtype, m, k, n))


@contextlib.contextmanager
def overrides(entries: Mapping[str, Tiling]) -> Iterator[None]:
    """Replace the active table with ``entries`` inside the context."""
    _stack.append(dict(entries))
    try:
        yield
    finally:
        _stack.pop()


@contextlib.contextmanager
def disabled() -> Iterator[None]:
    """Force kernel-default tilings inside the context."""
    _stack.append(None)
    try:
        yield
    finally:
        _stack.pop()
