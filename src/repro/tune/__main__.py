"""(Re)generate or check the checked-in kernel tuning table.

Generate (times candidate tilings per conv-site geometry, writes the
winners as deterministic JSON next to ``repro/tune/table.py``):

    PYTHONPATH=src python -m repro.tune \
        [--models darknet19 resnet18 tiny_yolo] [--sizes 32] \
        [--modes ideal] [--kernels trunk_conv cim_matmul] \
        [--batches 1 8] [--repeat 3] [--no-grid] [--full-sweep] \
        [--out PATH]

Check (static consistency of the checked-in table against the CURRENT
site enumeration — the CI smoke step; exits nonzero on drift):

    PYTHONPATH=src python -m repro.tune --check

Off-TPU the ``pallas_call`` grid candidates run in interpret mode —
slow to time and they never win there, so ``--no-grid`` (direct-lowering
candidates only) is the practical CPU setting; the default still races
the grid so a TPU run produces a real grid-vs-direct verdict.
"""

from __future__ import annotations

import argparse
import sys

from repro.tune import autotune, table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune",
                                 description=__doc__.split("\n\n")[0])
    ap.add_argument("--models", nargs="+",
                    default=["darknet19", "resnet18", "tiny_yolo"],
                    help="model families whose conv sites seed the table")
    ap.add_argument("--sizes", nargs="+", type=int, default=[32],
                    help="input resolutions to enumerate sites at")
    ap.add_argument("--modes", nargs="+", default=["ideal"],
                    choices=["ideal", "per_subarray", "bitserial"],
                    help="CiM fidelity modes to tune")
    ap.add_argument("--kernels", nargs="+",
                    default=["trunk_conv", "cim_matmul"],
                    choices=sorted(autotune.KERNEL_DEFAULTS),
                    help="kernels to tune per site geometry")
    ap.add_argument("--batches", nargs="+", type=int, default=[1, 8],
                    help="serving batch sizes to enumerate (the patch "
                         "GEMM's M axis is batch*OH*OW; 8 is the "
                         "CNNServer micro-batch default)")
    ap.add_argument("--repeat", type=int, default=3,
                    help="timing samples per candidate (best-of-k)")
    ap.add_argument("--no-grid", action="store_true",
                    help="skip pallas_call grid candidates (CPU setting)")
    ap.add_argument("--full-sweep", action="store_true",
                    help="sweep block_m/block_n for grid candidates too "
                         "(default: impl/dim-order/block_k only)")
    ap.add_argument("--out", default=None,
                    help="output path (default: the checked-in table)")
    ap.add_argument("--check", action="store_true",
                    help="verify the checked-in table against the current "
                         "site shapes instead of regenerating it")
    args = ap.parse_args(argv)

    if args.check:
        return 0 if autotune.check_table(args.out) else 1

    entries, meta = autotune.tune_table_for(
        tuple(args.models), tuple(args.sizes), tuple(args.modes),
        tuple(args.kernels), batches=tuple(args.batches),
        repeat=args.repeat, fast=not args.full_sweep,
        grid=not args.no_grid, log=print)
    out = args.out or table._DEFAULT_PATH
    table.save_table(entries, out, meta=meta)
    table.invalidate_cache()
    print(f"wrote {len(entries)} entries to {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
