"""Per-geometry kernel autotuning.

``repro.tune.table`` holds the checked-in tuning table the kernels
consult at call time; ``repro.tune.autotune`` contains the search
(imported lazily — it pulls in the kernels, which in turn import the
table, so eager import here would be circular).

Regenerate the table with ``python -m repro.tune``.
"""

from repro.tune import table
from repro.tune.table import Tiling, disabled, load_table, lookup, overrides, save_table

__all__ = ["table", "Tiling", "disabled", "load_table", "lookup",
           "overrides", "save_table", "autotune"]


def __getattr__(name):
    if name == "autotune":
        # importlib, not ``from repro.tune import autotune``: the from-
        # import resolves the name through THIS __getattr__ first and
        # would recurse before ever importing the submodule
        import importlib
        return importlib.import_module("repro.tune.autotune")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
