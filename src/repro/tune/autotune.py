"""Per-geometry kernel tiling search — the engine behind ``python -m repro.tune``.

The kernels accept ``block_m/n/k`` + grid ``dim_order`` + an ``impl``
choice (``pallas_call`` grid vs direct plain-XLA lowering) and consult
the checked-in tuning table (:mod:`repro.tune.table`) whenever the
caller leaves them unspecified.  This module fills that table: it
enumerates the *legal* candidate tilings for a GEMM geometry, verifies
each one bit-identical against the kernel-default path, times the
survivors (best-of-``repeat`` wall clock), and records the winners.

Legality is the load-bearing idea.  The fidelity modes fix the reduction
structure — per-k-block activation quantisation scales and ascending-K
accumulation — so a tiling that changes the k-partition changes the
bits, not just the speed.  :func:`legal_block_ks` therefore only emits
``block_k`` values that reproduce the default k-partition (same
per-block scales, same accumulation grouping); ``block_m``/``block_n``/
``dim_order``/``impl`` never touch the partition and are free axes.  On
top of the static argument, every candidate is *empirically* checked:
``np.array_equal`` against the default output, with mismatches dropped
(and reported) rather than tabulated.

Geometries come from the model families' conv site enumeration
(``plan/sites.py`` wraps ``models.cnn.conv_site_shapes``): each conv
site implies one patch-GEMM ``(M, K, N) = (N*OH*OW, KH*KW*C_in, C_out)``
that the ``trunk_conv`` / ``cim_matmul`` kernels key on.
"""

from __future__ import annotations

import dataclasses
import itertools
import time

import jax
import numpy as np

from repro.kernels.tiling import k_partition
from repro.tune import table as tune_table
from repro.tune.table import Tiling

# Per-kernel default tilings — must mirror the ``defaults=`` each kernel
# passes to resolve_tiling (the k-partition baseline legality is defined
# against).
KERNEL_DEFAULTS = {
    "cim_matmul": (128, 128, 512),
    "trunk_conv": (128, 128, 512),
    "rebranch_matmul": (128, 256, 512),
}

BLOCK_MS = (64, 128, 256)
BLOCK_NS = (64, 128, 256)
BLOCK_KS = (128, 256, 384, 512, 1024)
ROWS = 128                      # CiMConfig.rows_per_subarray default


@dataclasses.dataclass(frozen=True)
class Geometry:
    """One tunable kernel invocation shape (a table key plus the conv
    metadata needed to rebuild representative inputs)."""

    kernel: str                 # 'trunk_conv' | 'cim_matmul' | 'rebranch_matmul'
    mode: str                   # CiM fidelity mode
    dtype: str                  # activation dtype the kernel keys on
    m: int
    k: int
    n: int
    # trunk_conv only: (kernel size, c_in, c_out, input hw, stride, batch)
    conv: tuple | None = None

    @property
    def key(self) -> str:
        return tune_table.key(self.kernel, self.mode, self.dtype,
                              self.m, self.k, self.n)


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------

def legal_block_ks(k: int, rows: int = ROWS,
                   default_bk: int = 512) -> list[int]:
    """block_k values inducing the SAME k-partition as the default.

    The kernels clamp ``bk = min(block_k, round_up(k, rows))``, so for
    small contractions many block_k values collapse onto one partition;
    for large ones only the default survives.  Either way every value
    returned here is bit-neutral by construction (and re-checked
    empirically by the tuner).
    """
    base = k_partition(k, default_bk, rows)
    out, seen = [], set()
    for bk in sorted(set(BLOCK_KS) | {default_bk}):
        if bk % rows != 0 or k_partition(k, bk, rows) != base:
            continue
        eff = min(bk, -(-k // rows) * rows)   # the kernels' clamp rule
        if eff in seen:
            continue                          # same effective tiling
        seen.add(eff)
        out.append(bk)
    return out


def candidates(kernel: str, m: int, k: int, n: int, *,
               rows: int = ROWS, fast: bool = False) -> list[Tiling]:
    """Legal candidate tilings for one geometry, default-path first.

    The direct (plain-XLA) lowering only consumes ``block_k``; the
    ``pallas_call`` grid additionally sweeps ``block_m``/``block_n`` and
    the grid dim order.  ``fast`` restricts the grid sweep to the
    default block shape (the impl/dim-order comparison only) — what CI
    and the checked-in table generation use.
    """
    dm, dn, dk = KERNEL_DEFAULTS[kernel]
    bks = legal_block_ks(k, rows, dk)
    out: list[Tiling] = []
    for bk in bks:
        out.append(Tiling(dm, dn, bk, "mnk", "direct"))
    if fast:
        grid_ms, grid_ns = (dm,), (dn,)
    else:
        grid_ms, grid_ns = BLOCK_MS, BLOCK_NS
    for bm, bn, bk, order in itertools.product(grid_ms, grid_ns, bks,
                                               tune_table.DIM_ORDERS):
        out.append(Tiling(bm, bn, bk, order, "grid"))
    # drop duplicates while keeping order (direct candidates first)
    seen, uniq = set(), []
    for t in out:
        if t not in seen:
            seen.add(t)
            uniq.append(t)
    return uniq


# ---------------------------------------------------------------------------
# geometry enumeration from the model families' conv sites
# ---------------------------------------------------------------------------

def conv_geometries(models: tuple[str, ...], sizes: tuple[int, ...],
                    modes: tuple[str, ...], kernels: tuple[str, ...],
                    batches: tuple[int, ...] = (1,)) -> list[Geometry]:
    """Deduplicated tunable geometries over the families' conv sites.

    Each conv site becomes a ``trunk_conv`` geometry (float activations,
    the deployment path) and/or a ``cim_matmul`` one (int8 patches, the
    ``cim_conv`` fidelity path) keyed on the implied patch GEMM.

    ``batches`` enumerates serving batch sizes: the patch GEMM's M axis
    is ``batch * OH * OW``, so a micro-batched forward (CNNServer rides
    ``n_slots`` images per dispatch) hits DIFFERENT table keys than the
    solo shape — geometries the tuner would otherwise never have seen.
    The default keeps the historical solo-only enumeration.
    """
    from repro.models import cnn            # deferred: heavy import

    geoms: dict[str, Geometry] = {}
    for name, size, batch in itertools.product(models, sizes, batches):
        cfg = cnn.CNNConfig(name=name, input_size=size)
        for site, kk, c_in, c_out, out_hw, stride in cnn.conv_site_shapes(cfg):
            del site
            m, kdim = batch * out_hw * out_hw, kk * kk * c_in
            if m == 0:
                continue        # pooled below 1px at this input size:
                                # the kernels short-circuit empty outputs

            conv = (kk, c_in, c_out, out_hw * stride, stride, batch)
            for mode in modes:
                if "trunk_conv" in kernels:
                    g = Geometry("trunk_conv", mode, "float32",
                                 m, kdim, c_out, conv=conv)
                    geoms.setdefault(g.key, g)
                if "cim_matmul" in kernels:
                    g = Geometry("cim_matmul", mode, "int8",
                                 m, kdim, c_out, conv=conv)
                    geoms.setdefault(g.key, g)
                if "rebranch_matmul" in kernels:
                    g = Geometry("rebranch_matmul", mode, "float32",
                                 m, kdim, c_out, conv=conv)
                    geoms.setdefault(g.key, g)
    return list(geoms.values())


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def _runner(geom: Geometry):
    """A nullary callable running ``geom``'s kernel on deterministic
    representative inputs; tiling comes from the ambient table context."""
    import jax.numpy as jnp

    from repro.core import cim as cim_lib
    from repro.kernels.cim_matmul import cim_matmul_pallas
    from repro.kernels.rebranch_conv import trunk_conv_pallas
    from repro.kernels.rebranch_matmul import rebranch_matmul_pallas

    cfg = cim_lib.CiMConfig(mode=geom.mode)
    key = jax.random.PRNGKey(0)

    if geom.kernel == "trunk_conv":
        kk, c_in, c_out, hw, stride, batch = (*geom.conv, 1)[:6]
        x = jax.random.normal(key, (batch, hw, hw, c_in), jnp.float32)
        w_q = jax.random.randint(jax.random.fold_in(key, 1),
                                 (kk, kk, c_in, c_out), -127, 128, jnp.int8)
        w_scale = jnp.full((c_out,), 0.01, jnp.float32)

        def run(interpret=None):
            return trunk_conv_pallas(x, w_q, w_scale, cfg, stride=stride,
                                     padding="SAME", interpret=interpret)
        return run

    if geom.kernel == "cim_matmul":
        x_q = jax.random.randint(key, (geom.m, geom.k), -127, 128, jnp.int8)
        w_q = jax.random.randint(jax.random.fold_in(key, 1),
                                 (geom.k, geom.n), -127, 128, jnp.int8)

        def run(interpret=None):
            return cim_matmul_pallas(x_q, w_q, cfg, interpret=interpret)
        return run

    if geom.kernel == "rebranch_matmul":
        c_c = max(1, geom.k // 4)
        c_u = max(1, geom.n // 4)
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (geom.m, geom.k), jnp.float32)
        w_q = jax.random.randint(ks[1], (geom.k, geom.n), -127, 128, jnp.int8)
        w_scale = jnp.full((geom.n,), 0.01, jnp.float32)
        c = jax.random.normal(ks[2], (geom.k, c_c)) / np.sqrt(geom.k)
        core = jax.random.normal(ks[3], (c_c, c_u)) * 0.1
        u = jax.random.normal(ks[4], (c_u, geom.n)) / np.sqrt(c_u)

        def run(interpret=None):
            return rebranch_matmul_pallas(x, w_q, w_scale, c, core, u, cfg,
                                          interpret=interpret)
        return run

    raise ValueError(f"unknown tunable kernel {geom.kernel!r}")


def _time_best(fn, repeat: int) -> tuple[np.ndarray, float]:
    """(output, best-of-``repeat`` seconds); first call warms compilation."""
    out = np.asarray(jax.block_until_ready(fn()))
    best = float("inf")
    for _ in range(max(1, repeat)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return out, best


@dataclasses.dataclass(frozen=True)
class TuneResult:
    geometry: Geometry
    best: Tiling
    best_s: float
    default_s: float
    n_candidates: int
    n_mismatched: int           # candidates dropped by the bit check

    @property
    def speedup(self) -> float:
        return self.default_s / max(self.best_s, 1e-12)


def tune_geometry(geom: Geometry, *, repeat: int = 3, fast: bool = False,
                  grid: bool = True) -> TuneResult:
    """Search one geometry: verify + time every legal candidate.

    ``grid=False`` skips the ``pallas_call`` candidates entirely —
    off-TPU they run in interpret mode, where timing them is expensive
    and they never win; the direct candidates still race each other.
    """
    run = _runner(geom)
    with tune_table.disabled():
        ref, default_s = _time_best(run, repeat)

    cands = candidates(geom.kernel, geom.m, geom.k, geom.n, fast=fast)
    if not grid:
        cands = [c for c in cands if c.impl == "direct"]
    best, best_s, mismatched = None, float("inf"), 0
    for cand in cands:
        with tune_table.overrides({geom.key: cand}):
            # grid candidates need the explicit interpret flag off-TPU
            # (resolve_direct would otherwise route them to the direct
            # lowering and the measurement would be a lie)
            interpret = (jax.default_backend() != "tpu"
                         if cand.impl == "grid" else None)
            out, s = _time_best(lambda: run(interpret=interpret), repeat)
        if not np.array_equal(ref, out):
            mismatched += 1     # not bit-identical: never tabulated
            continue
        if s < best_s:
            best, best_s = cand, s
    assert best is not None, f"no legal candidate for {geom.key}"
    return TuneResult(geom, best, best_s, default_s,
                      n_candidates=len(cands), n_mismatched=mismatched)


# ---------------------------------------------------------------------------
# whole-table generation + consistency check
# ---------------------------------------------------------------------------

def tune_table_for(models: tuple[str, ...], sizes: tuple[int, ...],
                   modes: tuple[str, ...], kernels: tuple[str, ...], *,
                   batches: tuple[int, ...] = (1,), repeat: int = 3,
                   fast: bool = False, grid: bool = True,
                   log=None) -> tuple[dict[str, Tiling], dict]:
    """(entries, meta) for the conv-site geometries of ``models``."""
    geoms = conv_geometries(models, sizes, modes, kernels, batches)
    entries: dict[str, Tiling] = {}
    for i, geom in enumerate(geoms):
        res = tune_geometry(geom, repeat=repeat, fast=fast, grid=grid)
        entries[geom.key] = res.best
        if log is not None:
            log(f"[{i + 1}/{len(geoms)}] {geom.key}: "
                f"{res.best.impl}/{res.best.dim_order} "
                f"bm={res.best.block_m} bn={res.best.block_n} "
                f"bk={res.best.block_k}  "
                f"{res.best_s * 1e3:.2f}ms vs default "
                f"{res.default_s * 1e3:.2f}ms ({res.speedup:.2f}x, "
                f"{res.n_candidates} cands, {res.n_mismatched} dropped)")
    meta = {"models": sorted(models), "sizes": sorted(sizes),
            "modes": sorted(modes), "kernels": sorted(kernels),
            "batches": sorted(batches),
            "backend": jax.default_backend(), "fast": bool(fast),
            "grid": bool(grid), "repeat": int(repeat)}
    return entries, meta


def check_table(path: str | None = None, log=print) -> bool:
    """Is the checked-in table consistent with the current site shapes?

    Recomputes the expected key set from the table's own meta (models x
    sizes x modes x kernels) and verifies (a) every expected geometry
    has an entry, (b) no entry is stale (its key no longer enumerated),
    (c) every entry passes the static legality rules (subarray-aligned
    block_k reproducing the default k-partition).  Pure static checks —
    no kernels run — so CI can gate on it cheaply.
    """
    import json
    import os

    p = path or tune_table._DEFAULT_PATH
    if not os.path.exists(p):
        log(f"tuning table missing: {p}")
        return False
    with open(p) as f:
        doc = json.load(f)
    meta = doc.get("meta", {})
    entries = {k: Tiling.from_json(v)
               for k, v in doc.get("entries", {}).items()}
    required = ("models", "sizes", "modes", "kernels")
    if not all(meta.get(f) for f in required):
        log(f"table meta incomplete (need {required}): {sorted(meta)}")
        return False

    geoms = conv_geometries(tuple(meta["models"]),
                            tuple(int(s) for s in meta["sizes"]),
                            tuple(meta["modes"]), tuple(meta["kernels"]),
                            # older tables predate batched enumeration
                            tuple(int(b) for b in meta.get("batches", [1])))
    expected = {g.key: g for g in geoms}
    ok = True
    for key, g in sorted(expected.items()):
        if key not in entries:
            log(f"MISSING entry for current site geometry: {key}")
            ok = False
    for key, t in sorted(entries.items()):
        if key not in expected:
            log(f"STALE entry (geometry no longer enumerated): {key}")
            ok = False
            continue
        g = expected[key]
        dk = KERNEL_DEFAULTS[g.kernel][2]
        if t.block_k % ROWS != 0 or (k_partition(g.k, t.block_k, ROWS)
                                     != k_partition(g.k, dk, ROWS)):
            log(f"ILLEGAL block_k={t.block_k} for {key} "
                f"(changes the k-partition vs default {dk})")
            ok = False
    if ok:
        log(f"tuning table OK: {len(entries)} entries cover "
            f"{len(expected)} current site geometries")
    return ok
