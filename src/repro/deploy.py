"""`compile_model`: the one deployment entrypoint for every CiM model.

YOLoC deploys ONE network across heterogeneous substrates: most layers
frozen in ROM-CiM, a few (first/last, heads) kept SRAM-trainable, each
mapped per layer (paper §4, Fig. 12 area map).  ``compile_model`` is
where that mapping is decided:

    from repro import deploy
    model = deploy.compile_model(
        cfg,                       # ArchConfig (LMs) or cnn.CNNConfig
        engine="pallas",          # registry name or TrunkEngine instance
        layer_overrides={
            "convs.0": {"memory": "sram"},    # stem stays trainable
            "lm_head": {"memory": "sram"},    # readout stays trainable
            "blocks":  {"engine": "int8_native",
                         "cim": "per_subarray"},
        })
    params = model.init(key)
    logits = model.forward(params, batch)

It resolves the engine through the ``repro.engine`` registry (strict,
capability-gated), folds the per-layer override map into the config's
``rebranch_overrides`` (consumed by ``config.spec_for`` at each named
site), and returns a :class:`CompiledModel` bundling
init / forward / prefill / decode_step / init_cache (plus features /
apply_head for the chunked-loss training path).  Everything is resolved
at compile time — the returned closures contain no string dispatch — and
the config it produces is a frozen dataclass, safe as a jit static.

Override keys: ``engine`` (registry name), ``memory`` ('rom' freezes the
trunk, 'sram' keeps a plain trainable layer), ``cim`` (a CiMConfig or a
fidelity-mode string), ``branch_enabled``, ``d_ratio``, ``u_ratio``.
A full ``ReBranchSpec`` is also accepted verbatim.

The free functions in ``repro.models.api`` remain as thin deprecation
shims for existing callers; new code should compile once and reuse.
"""

from __future__ import annotations

import contextlib
import dataclasses

from repro import engine as engine_lib
from repro import plan as plan_lib
from repro.core.rebranch import ReBranchSpec
from repro.distributed import sharding as shd
from repro.engine.base import TrunkEngine
from repro.models import api, cnn
from repro.models.config import spec_for


def valid_sites(cfg) -> set | None:
    """The addresses ``layer_overrides`` / plan entries may use for this
    config: the family's enumerated site tree (leaf sites plus ancestor
    prefixes — see ``repro.plan.sites.valid_addresses``).  Typos and
    unwired sites are rejected by compile_model instead of silently
    doing nothing.  ``None`` means unconstrained (a model registered
    outside this package whose sites we cannot enumerate).
    """
    tree = plan_lib.try_site_tree(cfg)
    return None if tree is None else plan_lib.valid_addresses(tree)


class CompiledModel:
    """A model bound to its resolved engine(s) and per-layer mapping.

    Thin, stateless closures over a fully-resolved config: safe to build
    once and call from jit'd steps (the config is hashable/static).  LM
    configs (ArchConfig) expose the full serve surface; CNN configs
    (cnn.CNNConfig) expose init/forward (there is no KV cache to manage).
    """

    def __init__(self, cfg, engine: TrunkEngine, mesh=None, tune=None):
        self.cfg = cfg
        self.engine = engine
        self.mesh = mesh
        self.tune = tune
        self._is_cnn = isinstance(cfg, cnn.CNNConfig)
        self._draft_cfg = None          # lazy: see draft_cfg property
        if self._is_cnn:
            self._cnn_init, self._cnn_apply = cnn.MODEL_REGISTRY[cfg.name]

    @contextlib.contextmanager
    def _scope(self):
        """Activate the bound mesh (+ sharding rules) and the tuning-table
        policy around every model call, so compile-time binding works from
        plain jit sites — jax.jit(model.forward) traces under the mesh
        without the caller managing ``use_mesh``, and a ``tune=False``
        deployment pins kernel-default tilings for every kernel the trace
        reaches.  No-op when unbound (mesh=None, tune=None)."""
        with contextlib.ExitStack() as stack:
            if self.mesh is not None:
                stack.enter_context(shd.use_mesh(self.mesh))
                stack.enter_context(self.mesh)
            if self.tune is False:
                from repro import tune as tune_lib
                stack.enter_context(tune_lib.disabled())
            yield

    # -- mapping introspection ------------------------------------------
    def layer_spec(self, site: str) -> ReBranchSpec:
        """The ReBranchSpec governing a named site under this mapping."""
        return spec_for(self.cfg, site)

    # -- the model surface ----------------------------------------------
    def init(self, key):
        with self._scope():
            if self._is_cnn:
                return self._cnn_init(key, self.cfg)
            return api.init(key, self.cfg)

    def forward(self, params, batch):
        """Train-time forward: logits for LMs, head output for CNNs
        (batch is the token dict for LMs, the NHWC image for CNNs)."""
        with self._scope():
            if self._is_cnn:
                # constrain the NHWC input onto the serving layout (batch
                # over pod, spatial H over data — the halo-exchange conv's
                # native sharding); no-op unbound or on a 1-device mesh
                batch = shd.shard(batch, "cnn_batch", "cnn_h")
                return self._cnn_apply(params, batch, self.cfg)
            return api.forward(params, batch, self.cfg)

    def features(self, params, batch):
        self._lm_only("features")
        with self._scope():
            return api.features(params, batch, self.cfg)

    def apply_head(self, params, x):
        self._lm_only("apply_head")
        with self._scope():
            return api.apply_head(params, x, self.cfg)

    def prefill(self, params, batch, cache):
        self._lm_only("prefill")
        tokens = batch.get("tokens", batch.get("embeds"))
        if tokens is not None:
            self._check_cache("prefill", tokens, cache)
        with self._scope():
            return api.prefill(params, batch, self.cfg, cache)

    def decode_step(self, params, tokens, cache):
        self._lm_only("decode_step")
        self._check_cache("decode_step", tokens, cache)
        with self._scope():
            return api.decode_step(params, tokens, self.cfg, cache)

    # -- speculative decode surface (draft = branch-only, verify = full) --
    @property
    def draft_cfg(self):
        """The branch-only draft config (``api.draft_config``), built
        lazily and cached: same frozen-dataclass hygiene as ``cfg``, so
        it is safe as a jit static.  Shares this cell's params tree —
        ``trunk_skip`` is control flow, not weights."""
        if self._draft_cfg is None:
            self._lm_only("draft_cfg")
            self._draft_cfg = api.draft_config(self.cfg)
        return self._draft_cfg

    def verify_step(self, params, tokens, cache):
        """Speculative verify: one batched pass over a [B, k] token
        block through the FULL trunk+branch cell (k plain decode steps'
        worth of tokens in one dispatch).  Raises for families that
        cannot speculate (``api.supports_speculation``) and on cache /
        block geometry mismatches."""
        self._lm_only("verify_step")
        self._check_cache("verify_step", tokens, cache)
        with self._scope():
            return api.verify_step(params, tokens, self.cfg, cache)

    def draft_prefill(self, params, batch, cache):
        """``prefill`` through the branch-only draft cell (ROM trunks
        skipped).  Same params, same cache geometry — only the compute
        differs, so the draft KV state tracks the draft model exactly."""
        self._lm_only("draft_prefill")
        tokens = batch.get("tokens", batch.get("embeds"))
        if tokens is not None:
            self._check_cache("prefill", tokens, cache)
        with self._scope():
            return api.prefill(params, batch, self.draft_cfg, cache)

    def draft_decode_step(self, params, tokens, cache):
        """``decode_step`` through the branch-only draft cell — the
        token-proposal hot loop of speculative decode."""
        self._lm_only("draft_decode_step")
        self._check_cache("decode_step", tokens, cache)
        with self._scope():
            return api.decode_step(params, tokens, self.draft_cfg, cache)

    def init_cache(self, batch: int, max_len: int, dtype=None):
        self._lm_only("init_cache")
        return api.init_cache(self.cfg, batch, max_len, dtype)

    def init_paged_cache(self, rows: int, n_blocks: int, block_size: int,
                         max_len: int, dtype=None):
        """A paged KV cache for this model: ``n_blocks`` shared physical
        blocks of ``block_size`` positions plus per-row block tables
        (logical horizon ``max_len``).  Raises for families that cannot
        page — ssm/hybrid state and SWA rings (see
        ``api.supports_paging``) — and when ``block_size`` does not
        divide ``max_len`` (the gathered view must match the dense
        cache's attention geometry exactly)."""
        self._lm_only("init_paged_cache")
        return api.init_paged_cache(self.cfg, rows, n_blocks, block_size,
                                    max_len, dtype)

    def _check_cache(self, what: str, tokens, cache):
        """Catch cache/batch geometry mismatches at the model surface.

        A cache built for a different batch (or a prompt longer than the
        cache horizon) used to fail DEEP inside the model with an opaque
        XLA broadcast/scatter shape error; shapes are static, so the
        check is free at trace time and names both geometries.  Paged
        caches report their LOGICAL geometry (block-table rows x
        table_width*block_size), so the same checks cover both layouts.
        """
        n_batch, seq = tokens.shape[0], tokens.shape[1]
        cache_batch, horizon = api.cache_geometry(self.cfg, cache)
        first = api._first_layer(cache)
        paged = isinstance(first, dict) and "table" in first
        kind = "block-table rows" if paged else "cache rows"
        builder = ("init_paged_cache(rows={n}, ...)" if paged
                   else "init_cache(batch={n}, max_len=...)").format(
                       n=n_batch)
        if paged and what == "prefill":
            raise ValueError(
                "prefill cannot run against a paged cache (physical "
                "blocks have no per-row horizon to fill); prefill into "
                "a dense init_cache(1, max_len) cache and adopt the row "
                "into the paged pool (serve.pool.PagedPool.adopt)")
        if cache_batch != n_batch:
            raise ValueError(
                f"{what}: cache was built for batch={cache_batch} but "
                f"tokens have batch={n_batch} (tokens {tokens.shape} vs "
                f"{kind} {cache_batch}); build the cache with "
                f"{builder} or slice the batch to match")
        if what == "decode_step" and seq != 1:
            raise ValueError(
                f"decode_step consumes ONE token per sequence, got "
                f"tokens {tokens.shape} (seq={seq}); use prefill() for "
                f"multi-token inputs (or verify_step() for a "
                f"speculative k-token block)")
        if what == "verify_step" and horizon is not None and seq > horizon:
            raise ValueError(
                f"verify_step: speculative block width {seq} exceeds "
                f"the cache horizon {horizon} (every block entry needs "
                f"a cache position); shrink spec_k or grow max_len")
        if (what == "prefill" and horizon is not None
                and self.cfg.sliding_window == 0 and seq > horizon):
            raise ValueError(
                f"prefill: prompt length {seq} exceeds the cache horizon "
                f"{horizon} (full-attention cache holds max_len tokens); "
                f"build the cache with init_cache(batch, max_len>={seq})")

    def _lm_only(self, what: str):
        if self._is_cnn:
            raise NotImplementedError(
                f"{what}() is for autoregressive LMs; CNN configs "
                f"({self.cfg.name!r}) expose init/forward only")

    def __repr__(self):
        kind = "cnn" if self._is_cnn else self.cfg.family
        n_over = len(getattr(self.cfg, "rebranch_overrides", ()))
        mesh = "" if self.mesh is None else \
            " mesh=" + "x".join(str(self.mesh.shape[a])
                                for a in self.mesh.axis_names)
        return (f"<CompiledModel {self.cfg.name!r} ({kind}) "
                f"engine={self.engine.name!r} overrides={n_over}{mesh}>")


def compile_model(cfg, *, engine=None, layer_overrides=None, plan=None,
                  mesh=None, tune=None) -> CompiledModel:
    """Resolve engines + per-site ROM/SRAM placement and bundle the model.

    cfg: ArchConfig (any LM family) or models.cnn.CNNConfig.
    engine: registry name or TrunkEngine instance overriding the
        config-wide ``cfg.rebranch.trunk_impl``; None keeps the config's
        (or the plan's, when ``plan`` is given).
    layer_overrides: {address: override} map — see the module docstring
        for keys; addresses are leaf sites of the family's site tree
        ('blocks.attn' / 'blocks.ssm.in_proj' / 'lm_head' for LMs;
        'convs.N' / 'stem' / 'stages.S.B.convK' / 'head.N' for CNNs) or
        ancestor prefixes ('blocks', 'stages.1'); :func:`valid_sites`
        enumerates them and unknown addresses raise.  Values may also be
        full ReBranchSpec instances.  Thin constructor over ``plan``.
    plan: a :class:`repro.plan.PlacementPlan` — the canonical placement
        artifact, e.g. from the cost-driven solver::

            from repro import deploy, plan
            p = plan.solve(cfg, budget_mm2=200.0)     # Fig. 12 tradeoff
            model = deploy.compile_model(cfg, plan=p)

        The plan's default spec becomes the config-wide spec and its
        entries the per-site mapping; deploying under a plan is
        bit-identical to hand-writing the equivalent
        ``rebranch_overrides`` tuple.  Mutually exclusive with
        ``layer_overrides``; the plan must have been built for this
        config (``plan.model == cfg.name``).
    mesh: optional jax Mesh the model is deployed onto.  Every model call
        then traces under ``sharding.use_mesh(mesh)`` — the launch/mesh
        flow already does this for LM steps, so the parameter mainly
        serves CNN configs: the NHWC input is constrained to the
        batch-over-pod / H-over-data serving layout and sharded engines
        ('pallas_sharded') find their mesh without caller ceremony.
    tune: tuning-table policy for this deployment.  ``None`` (default)
        leaves the ambient policy alone — kernels of table-aware engines
        consult the checked-in ``repro.tune`` table as usual.  ``True``
        asserts the resolved engine actually HAS tuned kernels
        (``capabilities.tune``) and raises otherwise — deployments that
        budget on tuned timings fail fast instead of silently running
        fixed tilings.  ``False`` pins kernel-default tilings for every
        model call (``repro.tune.disabled()`` around the trace) — the
        A/B baseline the autotuner and benchmarks measure against.

    Every engine named anywhere in the mapping is resolved through the
    strict registry NOW — unknown engines and unsupported fidelity modes
    fail here, not mid-trace.
    """
    if plan is not None:
        if layer_overrides:
            raise ValueError(
                "pass either plan= or layer_overrides=, not both "
                "(a PlacementPlan already carries the whole mapping)")
        if plan.model != cfg.name:
            raise ValueError(
                f"plan was built for {plan.model!r}, not {cfg.name!r}")
        base = plan.default
    else:
        base = cfg.rebranch
    if engine is not None:
        name = engine.name if isinstance(engine, TrunkEngine) else engine
        if isinstance(engine, TrunkEngine):
            # Instance given: it must BE the registry entry for its name
            # (layers re-resolve by name at trace time, so silently
            # replacing the entry would swap the engine under every other
            # compiled model).  Unregistered names are added; conflicts
            # must be resolved explicitly by the caller.
            if name not in engine_lib.registered_names():
                engine_lib.register(name, engine)
            elif engine_lib.get(name) is not engine:
                raise ValueError(
                    f"engine instance named {name!r} conflicts with the "
                    f"already-registered engine of that name; call "
                    f"repro.engine.register({name!r}, eng, override=True) "
                    f"explicitly if you mean to replace it globally, or "
                    f"give the instance a distinct name")
        base = dataclasses.replace(base, trunk_impl=name)
    eng = engine_lib.resolve(base)          # strict + capability gate
    if tune is True and not eng.capabilities.tune:
        raise ValueError(
            f"tune=True but engine {eng.name!r} has no tuned kernels "
            f"(capabilities.tune is False); deploy on a table-aware "
            f"engine ('pallas'/'pallas_fused'/'pallas_sharded') or drop "
            f"the flag")

    if plan is None:
        # layer_overrides is the thin constructor: build the plan from the
        # dict (site-tree validation + override normalisation live there)
        # and MERGE over any overrides the config already carries
        plan = plan_lib.PlacementPlan.build(cfg, layer_overrides,
                                            default=base)
        merged = dict(getattr(cfg, "rebranch_overrides", ()))
        merged.update(plan.as_overrides())
    else:
        # an explicit plan is CANONICAL: it replaces the config's mapping
        # wholesale (a stale leaf override would out-length and shadow a
        # plan's ancestor-prefix entry under longest-prefix resolution)
        merged = dict(plan.as_overrides())
    for site, spec in merged.items():
        if spec.enabled:
            engine_lib.resolve(spec)        # gate per-layer engines too

    cfg = dataclasses.replace(cfg, rebranch=base,
                              rebranch_overrides=tuple(sorted(merged.items())))
    return CompiledModel(cfg, eng, mesh=mesh, tune=tune)
