"""Branch extraction and validation: the swappable half of a deployment.

YOLoC's premise is that the ROM trunk never moves — only the small SRAM
state (ReBranch cores, BN statistics, biases, SRAM-resident sites and
heads) adapts the chip to a new dataset or task.  That SRAM state is
exactly the *trainable* side of ``rebranch.partition``, so a "scenario"
is nothing more than one trained branch tree over a fixed trunk.

This module turns that observation into checked artifacts:

  * :func:`split_params`      — (branch, trunk) halves of a params tree.
  * :func:`branch_template`   — the shape/dtype skeleton a valid branch
    for a compiled model must match (no allocation: ``jax.eval_shape``).
  * :func:`validate_branch`   — geometry-style structure check naming
    the expected vs found tree, mirroring the serve layer's
    ``cache_geometry`` errors.
  * :func:`plan_fingerprint`  — a stable hash of a
    :class:`~repro.plan.PlacementPlan`: a branch trained under one
    placement can never be implanted onto a mismatched one (a site that
    flipped ROM<->SRAM changes which tensors even exist in the branch).
  * :class:`BranchBundle` / :func:`extract` / :func:`implant` — a branch
    tree tagged with its model + plan fingerprint, and the validated
    way to put one back onto a resident trunk.
  * :func:`swap_params`       — the donated in-place combine the serving
    layer uses at decode-step boundaries: the trunk leaves alias through
    (zero ROM traffic), the old branch buffers are donated, and only the
    new branch values are written.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rebranch


# ---------------------------------------------------------------------------
# plan fingerprint
# ---------------------------------------------------------------------------

def _spec_token(spec) -> str:
    """Canonical, process-stable serialization of a ReBranchSpec."""
    cim = spec.cim
    return repr((
        spec.d_ratio, spec.u_ratio, spec.enabled, spec.trunk_impl,
        spec.branch_enabled, jnp.dtype(spec.param_dtype).name,
        (cim.mode, cim.rows_per_subarray, cim.adc_bits, cim.act_bits,
         cim.weight_bits, cim.act_group_bits, cim.adc_range_frac,
         cim.psum_range_frac)))


def plan_fingerprint(plan) -> str:
    """Stable hex digest of a PlacementPlan's full mapping.

    ``hash(plan)`` is salted per process; this digest is what branch
    checkpoints and :class:`BranchBundle` carry so a branch trained
    under one placement is rejected by any other.  ``None`` (a family
    outside the placement subsystem) gets a distinguished constant.
    """
    if plan is None:
        return "no-plan"
    h = hashlib.sha256()
    h.update(plan.model.encode())
    h.update(_spec_token(plan.default).encode())
    for addr, spec in plan.entries:
        h.update(addr.encode())
        h.update(_spec_token(spec).encode())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# split / template / validation
# ---------------------------------------------------------------------------

def split_params(params) -> tuple[Any, Any]:
    """(branch, trunk): the swappable SRAM tree and the frozen ROM tree.

    Both halves keep the full tree structure with ``None`` at the other
    half's positions, so ``rebranch.combine(branch, trunk)`` rebuilds
    the exact params tree.
    """
    branch, trunk = rebranch.partition(params)
    return branch, trunk


def branch_template(model):
    """The branch skeleton (ShapeDtypeStruct leaves) a valid branch for
    ``model`` must match — computed via eval_shape, no allocation."""
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    return rebranch.partition(shapes)[0]


def _leaf_index(tree) -> dict[str, Any]:
    pairs = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): leaf for p, leaf in pairs
            if leaf is not None}


def _preview(names, n=4) -> str:
    names = sorted(names)
    shown = ", ".join(names[:n])
    more = len(names) - n
    return shown + (f", ... ({more} more)" if more > 0 else "")


def validate_branch(branch, template, *, where: str = "branch") -> None:
    """Structure + shape/dtype check of a branch tree against a template.

    Raises a geometry-style ValueError naming the expected vs found
    structure (mirrors the serve layer's cache_geometry errors) instead
    of letting a mismatch surface as a raw treedef/flatten error deep
    inside ``combine`` or jit.
    """
    got = _leaf_index(branch)
    want = _leaf_index(template)
    missing = set(want) - set(got)
    unexpected = set(got) - set(want)
    if missing or unexpected:
        parts = []
        if missing:
            parts.append(f"missing tensors {_preview(missing)}")
        if unexpected:
            parts.append(f"unexpected tensors {_preview(unexpected)}")
        raise ValueError(
            f"{where}: branch tree does not match the deployment's "
            f"branch structure ({'; '.join(parts)}; expected "
            f"{len(want)} swappable tensors, found {len(got)}) — was "
            f"this branch extracted under a different placement plan "
            f"or model config?")
    for name, leaf in want.items():
        g = np.asarray(got[name]) if not hasattr(got[name], "shape") \
            else got[name]
        g_shape, g_dtype = tuple(g.shape), jnp.dtype(g.dtype)
        if g_shape != tuple(leaf.shape):
            raise ValueError(
                f"{where}: tensor {name} has shape {g_shape} but the "
                f"deployment expects {tuple(leaf.shape)} — branch was "
                f"trained for a different geometry")
        if g_dtype != jnp.dtype(leaf.dtype):
            raise ValueError(
                f"{where}: tensor {name} has dtype {g_dtype} but the "
                f"deployment expects {jnp.dtype(leaf.dtype)}")


# ---------------------------------------------------------------------------
# bundles: a branch tagged with its provenance
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BranchBundle:
    """One scenario's swappable state plus the keys that make it safe:
    the model name and the placement-plan fingerprint it was extracted
    under.  ``implant`` refuses a bundle whose fingerprint does not
    match the target deployment's plan."""
    model: str
    plan_fp: str
    params: Any                          # branch tree (trunk slots None)


def extract(model, params, plan) -> BranchBundle:
    """Pull the swappable branch out of a full params tree, validated
    against ``model``'s branch template and tagged with ``plan``."""
    branch, _ = split_params(params)
    validate_branch(branch, branch_template(model), where="extract")
    return BranchBundle(model=model.cfg.name,
                        plan_fp=plan_fingerprint(plan), params=branch)


def implant(model, params, bundle: BranchBundle, plan, *,
            donate: bool = True):
    """Put a bundle's branch onto ``params``'s resident trunk.

    Checks model identity and the plan fingerprint, validates the tree
    geometry, then performs the (by default donated) swap: trunk leaves
    alias through untouched (zero ROM traffic), old branch buffers are
    freed.
    """
    if bundle.model != model.cfg.name:
        raise ValueError(
            f"implant: bundle was extracted from model "
            f"{bundle.model!r}, not {model.cfg.name!r}")
    fp = plan_fingerprint(plan)
    if bundle.plan_fp != fp:
        raise ValueError(
            f"implant: bundle was extracted under placement plan "
            f"{bundle.plan_fp} but this deployment runs plan {fp}; a "
            f"branch is only valid on the placement it was trained "
            f"against (a ROM<->SRAM flip changes which tensors exist)")
    validate_branch(bundle.params, branch_template(model), where="implant")
    return swap_params(params, bundle.params, donate=donate)


# ---------------------------------------------------------------------------
# the donated swap
# ---------------------------------------------------------------------------

def _combine(params, branch):
    # trunk leaves pass through (under donation they alias in place — the
    # ROM never moves); old branch buffers are freed, new values written
    return rebranch.combine(branch, rebranch.partition(params)[1])


_swap_donated = jax.jit(_combine, donate_argnums=(0,))
_swap_copy = jax.jit(_combine)


def swap_params(params, branch, *, donate: bool = True):
    """Replace the branch half of ``params`` with ``branch``.

    With ``donate=True`` (the serving default) ``params`` is DONATED:
    trunk buffers alias through in place (zero ROM traffic) and the old
    branch buffers are freed, but the caller must drop every outside
    reference to the tree — including previously split trunk views —
    and use the returned one.  ``donate=False`` copies instead, for
    callers that keep the original tree alive (A/B comparisons,
    benchmarks racing two scenarios side by side).  ``branch`` is never
    donated — a cached scenario-store copy stays valid across
    arbitrarily many swaps.
    """
    return (_swap_donated if donate else _swap_copy)(params, branch)
