"""ScenarioStore: N registered scenarios over ONE resident trunk.

A scenario is a named branch tree (see :mod:`repro.scenario.branch`)
trained against a fixed ROM trunk under a fixed placement plan.  The
store owns the host-side sources — in-memory branch trees, tagged
:class:`~repro.scenario.branch.BranchBundle`\\ s, or branch-only
checkpoints written by ``repro.checkpoint.manager.save_branch`` — and
an LRU cache of device-resident copies, so hot scenarios swap in O(one
donated combine) while cold ones stay off-device.

Resolution is strict, like ``repro.engine`` and ``repro.serve``:
unknown scenario names raise with the registered set, and every source
is validated (tree geometry at register time for in-memory sources,
plan fingerprint + geometry at load time for checkpoints) so a branch
from a mismatched placement fails at the front door, not mid-decode.
"""

from __future__ import annotations

import collections

import jax
import numpy as np

from repro.scenario import branch as branch_lib


class ScenarioStore:
    """Named branch sources + an LRU device cache for one deployment.

    model / plan: the resident cell the branches must fit (the branch
        template and the plan fingerprint both derive from them).
    capacity: max device-resident branches.  Eviction is LRU — a swap
        to an evicted scenario reloads from the host source (still no
        trunk traffic; the trunk never left the device).
    """

    def __init__(self, model, plan, *, capacity: int = 4):
        if capacity < 1:
            raise ValueError(f"need capacity >= 1, got {capacity}")
        self.model = model
        self.plan = plan
        self.plan_fp = branch_lib.plan_fingerprint(plan)
        self.capacity = int(capacity)
        self.template = branch_lib.branch_template(model)
        self._sources: dict[str, tuple] = {}   # name -> (kind, payload)
        self._cache: collections.OrderedDict = collections.OrderedDict()
        self.evicted: list[str] = []           # eviction order, oldest first
        self.hits = 0
        self.misses = 0

    # -- registration ----------------------------------------------------
    def register(self, name: str, branch=None, *, bundle=None,
                 ckpt_dir: str | None = None,
                 override: bool = False) -> None:
        """Register one scenario from exactly one source.

        branch: an in-memory branch tree (validated now, snapshotted to
            host so later mutation/donation of the caller's copy cannot
            corrupt the store).
        bundle: a BranchBundle — its plan fingerprint must match this
            deployment's plan.
        ckpt_dir: a directory holding ``save_branch`` output for
            ``name``; fingerprint + geometry are validated at load.
        """
        n_sources = sum(x is not None for x in (branch, bundle, ckpt_dir))
        if n_sources != 1:
            raise ValueError(
                f"scenario {name!r}: pass exactly one of branch=, "
                f"bundle=, ckpt_dir= (got {n_sources})")
        if name in self._sources and not override:
            raise ValueError(
                f"scenario {name!r} already registered; pass "
                f"override=True to replace it")
        if bundle is not None:
            if bundle.model != self.model.cfg.name:
                raise ValueError(
                    f"scenario {name!r}: bundle is for model "
                    f"{bundle.model!r}, this store serves "
                    f"{self.model.cfg.name!r}")
            if bundle.plan_fp != self.plan_fp:
                raise ValueError(
                    f"scenario {name!r}: bundle was extracted under "
                    f"placement plan {bundle.plan_fp} but this "
                    f"deployment runs plan {self.plan_fp}; refusing a "
                    f"branch from a mismatched placement")
            branch = bundle.params
        if branch is not None:
            branch_lib.validate_branch(branch, self.template,
                                       where=f"scenario {name!r}")
            host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                branch)
            self._sources[name] = ("host", host)
        else:
            self._sources[name] = ("ckpt", ckpt_dir)
        self._cache.pop(name, None)            # stale device copy, if any

    def names(self) -> list[str]:
        """Every registered scenario name, sorted (the set a bad
        ``get`` reports)."""
        return sorted(self._sources)

    def cached(self) -> list[str]:
        """Device-resident scenario names, least-recently-used first."""
        return list(self._cache)

    # -- lookup ----------------------------------------------------------
    def get(self, name: str):
        """The device-resident branch tree for ``name`` (LRU-cached)."""
        if name in self._cache:
            self._cache.move_to_end(name)
            self.hits += 1
            return self._cache[name]
        try:
            kind, payload = self._sources[name]
        except KeyError:
            raise KeyError(
                f"unknown scenario {name!r}; registered: "
                f"{self.names()}") from None
        self.misses += 1
        if kind == "host":
            branch = jax.tree.map(jax.numpy.asarray, payload)
        else:
            from repro.checkpoint import manager as ckpt
            branch = ckpt.restore_branch(payload, name, self.template,
                                         plan=self.plan)
            branch = jax.tree.map(jax.numpy.asarray, branch)
        self._cache[name] = branch
        while len(self._cache) > self.capacity:
            old, _ = self._cache.popitem(last=False)
            self.evicted.append(old)
        return branch

    def evict(self, name: str | None = None) -> None:
        """Drop one (or every) device-resident copy; sources stay."""
        if name is None:
            self._cache.clear()
        else:
            self._cache.pop(name, None)

    def __len__(self) -> int:
        return len(self._sources)

    def __contains__(self, name: str) -> bool:
        return name in self._sources

    def __repr__(self):
        return (f"<ScenarioStore {self.model.cfg.name!r} "
                f"scenarios={self.names()} cached={len(self._cache)}/"
                f"{self.capacity} plan={self.plan_fp}>")
