"""Scenario multiplexing: many workloads over one resident ROM trunk.

The paper's deployment premise — ROM weights are physically immutable,
only the small SRAM ReBranch adapts — means switching a chip between
datasets/tasks is a *branch* swap, not a model reload.  CIMPool (arXiv
2503.22044) scales the same shared-weight-pool idea past one network.
This package makes that a first-class subsystem:

  * :mod:`repro.scenario.branch` — split a params tree into the frozen
    trunk and the swappable branch, validate branch geometry against a
    deployment, fingerprint placement plans, and perform the donated
    in-place swap (zero trunk recompile, zero ROM traffic).
  * :mod:`repro.scenario.store`  — :class:`ScenarioStore`: named branch
    sources (in-memory, bundles, branch-only checkpoints) with an LRU
    device cache.

The serving layer (``repro.serve``) wires stores to resident cells:
``serve.load(model_id, scenario=...)`` and ``LMServer.swap_scenario``
swap branches at decode-step boundaries, with in-flight requests
finishing on the scenario they were admitted under.
"""

from repro.scenario.branch import (BranchBundle, branch_template,  # noqa: F401
                                   extract, implant, plan_fingerprint,
                                   split_params, swap_params,
                                   validate_branch)
from repro.scenario.store import ScenarioStore  # noqa: F401
