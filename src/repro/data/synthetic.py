"""Deterministic, shardable, resumable synthetic data pipelines.

Every batch is a pure function of (seed, step, shard) — the pipeline has
NO mutable state, so:
  * any host can produce any shard of any step (straggler takeover,
    elastic re-sharding need no data-state migration);
  * resume-after-restart is exact (the checkpoint stores only `step`).

The LM stream is a learnable-structure language: a fixed random Markov
chain over the vocabulary (temperature-controlled), so cross-entropy has
a real floor and training curves are meaningful, not just noise-fitting.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 128
    seq_len: int = 64
    global_batch: int = 8
    num_codebooks: int = 0         # musicgen-style multi-stream tokens
    branch_factor: int = 8         # Markov out-degree (structure strength)


def _transition_table(cfg: DataConfig) -> np.ndarray:
    """[V, branch] successor table — the 'language' all batches share."""
    rng = np.random.default_rng(cfg.seed + 1000)
    return rng.integers(0, cfg.vocab_size,
                        size=(cfg.vocab_size, cfg.branch_factor))


def markov_batch(cfg: DataConfig, step: int,
                 shard: int = 0, num_shards: int = 1) -> dict:
    """Batch for `step`, restricted to this host's shard of the batch."""
    assert cfg.global_batch % num_shards == 0
    per = cfg.global_batch // num_shards
    table = _transition_table(cfg)
    rng = np.random.default_rng(
        (cfg.seed * 1_000_003 + step) * 65_537 + shard)
    q = cfg.num_codebooks if cfg.num_codebooks else 1
    toks = np.empty((per, cfg.seq_len + 1, q), np.int32)
    toks[:, 0] = rng.integers(0, cfg.vocab_size, size=(per, q))
    choices = rng.integers(0, cfg.branch_factor,
                           size=(per, cfg.seq_len, q))
    for t in range(cfg.seq_len):
        toks[:, t + 1] = np.take_along_axis(
            table[toks[:, t]], choices[:, t][..., None], axis=-1)[..., 0]
    if not cfg.num_codebooks:
        toks = toks[..., 0]
    return {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }


def entropy_floor(cfg: DataConfig) -> float:
    """The exact CE floor of the Markov language (nats/token)."""
    # successors drawn uniformly from `branch` entries (with collisions)
    table = _transition_table(cfg)
    ent = 0.0
    for v in range(cfg.vocab_size):
        _, counts = np.unique(table[v], return_counts=True)
        p = counts / counts.sum()
        ent += -(p * np.log(p)).sum()
    return float(ent / cfg.vocab_size)


def image_batch(seed: int, step: int, batch: int, size: int,
                num_classes: int, shard: int = 0, num_shards: int = 1):
    """Synthetic class-conditional texture 'dataset', deliberately HARD:
    classes are second-order combinations of overlapping frequency pairs
    with per-image random phase/contrast/shift and strong noise, so a
    linear probe on generic features underperforms and fine-tuning (full
    or branch) has headroom — transfer-learning comparisons behave like
    real datasets."""
    assert batch % num_shards == 0
    per = batch // num_shards
    rng = np.random.default_rng((seed * 7_919 + step) * 257 + shard)
    labels = rng.integers(0, num_classes, size=(per,))
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size
    imgs = np.empty((per, size, size, 3), np.float32)
    for i, c in enumerate(labels):
        crng = np.random.default_rng(seed * 31 + int(c))   # class style
        # overlapping frequency pool: classes differ in the *pairing* of
        # x/y components per channel, not in which frequencies exist
        f1 = 2 + (crng.integers(0, 5, size=3))             # in {2..6}
        f2 = 2 + (crng.integers(0, 5, size=3))
        sgn = crng.choice([-1.0, 1.0], size=3)
        shift = rng.uniform(0, 1, size=2)                  # per-IMAGE jitter
        contrast = rng.uniform(0.8, 1.2)
        chans = []
        for ch in range(3):
            g1 = np.sin(2 * np.pi * f1[ch] * (xx + shift[0]))
            g2 = np.sin(2 * np.pi * f2[ch] * (yy + shift[1]))
            chans.append(g1 * g2 * sgn[ch])                # 2nd-order cue
        base = contrast * np.stack(chans, axis=-1)
        imgs[i] = base + 0.6 * rng.standard_normal((size, size, 3))
    return jnp.asarray(imgs), jnp.asarray(labels)
