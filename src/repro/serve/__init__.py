"""Serving runtime: continuous batching over one resident ROM cell.

The paper's premise is that ROM-CiM weights never move — so a compiled
cell should amortize across as many concurrent users as the scheduler
can feed it.  This package owns requests on top of
``repro.deploy.compile_model``:

  * :mod:`repro.serve.registry`  — model-id -> (config, plan, engine,
    tune) entries, compiled lazily into ONE resident
    :class:`~repro.deploy.CompiledModel` per id (the exo
    ``model_base_shards`` shape: ids are data, deployment is a lookup),
    with an optional LRU residency cap (``set_max_resident``) evicting
    the least-recently-used cell through the same ``evict`` path.
  * :mod:`repro.serve.pool`      — KV-cache pools sized from the
    :class:`~repro.plan.PlacementPlan`'s SRAM residency stats (weights
    already resident in SRAM shrink the activation/KV budget): the
    dense per-request ``SlotPool`` and the ``PagedPool``, which carves
    the same byte budget into fixed-size blocks shared through
    per-request block tables (short requests stop paying full-horizon
    bytes).
  * :mod:`repro.serve.scheduler` — admission queue + continuous-batching
    scheduler: solo prefills (whole-prompt or chunked, interleaved with
    decode steps) join the batch at decode-step boundaries, finished
    requests retire without draining the batch, and every request's
    output is bit-identical to a solo prefill+decode run.  With
    ``spec_k > 0`` the scheduler decodes speculatively: the ReBranch
    branch (``trunk_skip`` draft config, same params tree) proposes k
    tokens per row, one batched ``verify_step`` through the full cell
    checks them, and rejected tails roll back in the pool — greedy
    output stays bit-identical to plain decode.
  * :mod:`repro.serve.server`    — the async front door shared by LM
    decode serving and ``cnn.CNNConfig`` forward-only serving:
    ``serve.load(model_id)`` returns a server with ``submit``.

Scenario multiplexing (``repro.scenario``): one resident cell serves N
registered scenarios.  ``registry.scenario_store(model_id)`` holds the
named branches (LRU device cache over host/checkpoint sources) and
``serve.load(model_id, scenario=...)`` / ``LMServer.swap_scenario``
hot-swap the SRAM branch over the fixed ROM trunk at decode-step
boundaries — zero trunk recompile, zero ROM traffic, in-flight
requests finish on the scenario they were admitted under.
"""

from repro.serve.pool import (PagedPool, SlotPool,        # noqa: F401
                              suggest_paged, suggest_slots)
from repro.serve.registry import (ModelEntry, compile_entry,  # noqa: F401
                                  evict, has_scenarios, max_resident,
                                  register, registered_ids, resident_ids,
                                  resolve, scenario_store,
                                  set_max_resident)
from repro.serve.scheduler import ContinuousBatcher, Request  # noqa: F401
from repro.serve.server import CNNServer, LMServer, load  # noqa: F401
