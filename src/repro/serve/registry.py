"""Model registry: model-id -> (config, plan, engine, tune) -> one cell.

The serving analogue of exo's ``model_base_shards`` map (SNIPPETS.md §1):
a model id is data, and everything needed to deploy it — the config
factory, the placement plan, the engine and the tuning policy — hangs
off that id.  ``compile_entry`` resolves an id into a
:class:`~repro.deploy.CompiledModel` exactly once per process: the ROM
trunk is immutable and never moves, so the compiled cell is a resident
singleton that every server/scheduler for that id shares.

Resolution is strict, like ``repro.engine``: unknown ids raise with the
registered set, so a typo'd model id fails at the front door instead of
deploying a default config.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable

from repro import configs, deploy
from repro import plan as plan_lib


@dataclasses.dataclass(frozen=True)
class ModelEntry:
    """Everything needed to deploy one model id.

    config: zero-arg factory returning the config (ArchConfig or
        cnn.CNNConfig).  A factory, not an instance, so registering the
        whole zoo costs nothing until an id is actually served.
    plan: optional ``cfg -> PlacementPlan`` factory.  ``None`` means
        "solve the minimum-area design point" when the family has an
        enumerable site tree (the YOLoC all-ROM+branch deployment), or
        no plan for families outside the placement subsystem.
    engine / tune: forwarded to ``deploy.compile_model``.
    scenarios: optional ((name, factory), ...) of pre-registered branch
        scenarios; each factory is ``(model, plan) -> branch tree`` and
        seeds the id's :class:`~repro.scenario.ScenarioStore` lazily on
        first ``scenario_store(model_id)``.  One compiled resident cell
        then serves every registered scenario by branch hot-swap.
    """
    model_id: str
    config: Callable[[], Any]
    plan: Callable[[Any], Any] | None = None
    engine: str | None = None
    tune: bool | None = None
    scenarios: tuple = ()


_REGISTRY: dict[str, ModelEntry] = {}
_COMPILED: dict[str, tuple] = {}          # id -> (CompiledModel, plan),
                                          # LRU-ordered: oldest first
_STORES: dict[str, Any] = {}              # id -> ScenarioStore
_LOCK = threading.Lock()
_MAX_RESIDENT: int | None = None          # None -> unbounded residency


def register(entry: ModelEntry, *, override: bool = False) -> ModelEntry:
    """Publish ``entry`` under its model id.  Raises ValueError on a
    duplicate id unless ``override=True``; overriding also drops the
    id's resident cell and scenario store (branches validated against
    the old cell's geometry must never implant onto the new one)."""
    with _LOCK:
        if entry.model_id in _REGISTRY and not override:
            raise ValueError(
                f"model id {entry.model_id!r} already registered; pass "
                f"override=True to replace it")
        _REGISTRY[entry.model_id] = entry
        # a re-registered entry invalidates BOTH the resident cell and
        # its scenario store: branches validated against the old cell's
        # geometry must never implant onto the new one.  compile_entry
        # additionally re-checks entry identity before publishing a
        # cell, so a compile racing this register can't resurrect the
        # stale entry's cell either.
        _drop(entry.model_id)
    return entry


def _drop(model_id: str) -> bool:
    """Drop one id's resident cell and scenario store (caller holds
    ``_LOCK``).  The single eviction path: explicit :func:`evict`, entry
    re-registration, and the LRU cap all funnel through here.  Returns
    whether a resident cell was actually dropped."""
    dropped = _COMPILED.pop(model_id, None) is not None
    _STORES.pop(model_id, None)
    return dropped


def evict(model_id: str) -> bool:
    """Drop the resident cell (and scenario store) for ``model_id``;
    the next ``compile_entry`` recompiles from the registered entry.
    Returns whether a cell was resident (False -> nothing to drop)."""
    with _LOCK:
        return _drop(model_id)


def set_max_resident(n: int | None) -> None:
    """Cap how many compiled cells stay resident at once (LRU).

    Real YOLoC silicon holds ONE ROM trunk; this process-level registry
    can deploy many smoke cells, and each resident cell pins its jit
    executables and any scenario store.  With a cap, compiling (or
    touching, via ``compile_entry``) an id past the cap evicts the
    least-recently-used resident — through the same :func:`evict` path
    a caller would use — and the evicted id transparently recompiles on
    its next load.  ``None`` removes the cap (the default)."""
    global _MAX_RESIDENT
    if n is not None and n < 1:
        raise ValueError(f"max_resident must be >= 1 or None, got {n}")
    with _LOCK:
        _MAX_RESIDENT = n
        _evict_over_cap()


def max_resident() -> int | None:
    """The current residency cap (``None`` -> unbounded)."""
    return _MAX_RESIDENT


def resident_ids() -> list[str]:
    """Ids with a compiled resident cell, least-recently-used first
    (the head is the next LRU eviction victim)."""
    with _LOCK:
        return list(_COMPILED)


def _touch(model_id: str) -> None:
    """Move an id to the most-recently-used end (caller holds _LOCK)."""
    if model_id in _COMPILED:
        _COMPILED[model_id] = _COMPILED.pop(model_id)


def _evict_over_cap() -> None:
    """Evict LRU residents until under the cap (caller holds _LOCK)."""
    if _MAX_RESIDENT is None:
        return
    while len(_COMPILED) > _MAX_RESIDENT:
        _drop(next(iter(_COMPILED)))       # dict order: oldest first


def registered_ids() -> list[str]:
    """Every registered model id, sorted (the set a bad id reports)."""
    return sorted(_REGISTRY)


def resolve(model_id: str) -> ModelEntry:
    """The entry for ``model_id``.  Unknown ids raise KeyError naming
    the registered set — a typo fails at the front door, not by
    deploying a default config."""
    try:
        return _REGISTRY[model_id]
    except KeyError:
        raise KeyError(
            f"unknown model id {model_id!r}; registered: "
            f"{registered_ids()}") from None


def compile_entry(model_id: str):
    """The resident cell for ``model_id``: (CompiledModel, plan).

    Compiled at most once per process — repeated loads (more servers,
    more schedulers) share the same deployed cell, which is the whole
    point of ROM residency.
    """
    while True:
        with _LOCK:
            if model_id in _COMPILED:
                _touch(model_id)           # LRU: a hit is a use
                return _COMPILED[model_id]
        entry = resolve(model_id)
        cfg = entry.config()
        if entry.plan is not None:
            plan = entry.plan(cfg)
        else:
            # default: the minimum-area YOLoC design point, when the
            # family has an enumerable site tree (plan stats then size
            # the KV pool)
            plan = (plan_lib.solve(cfg, None, engine=entry.engine)
                    if plan_lib.try_site_tree(cfg) is not None else None)
        model = deploy.compile_model(
            cfg, plan=plan,
            engine=None if plan is not None else entry.engine,
            tune=entry.tune)
        with _LOCK:
            if _REGISTRY.get(model_id) is not entry:
                continue    # entry re-registered mid-compile: this cell
                            # is stale — never publish it (it would
                            # silently serve the OLD entry's config)
            # lost race against an identical compile: keep the first
            cell = _COMPILED.setdefault(model_id, (model, plan))
            _touch(model_id)               # newest use -> MRU end
            _evict_over_cap()
            return cell


def has_scenarios(model_id: str) -> bool:
    """True when the id has a live store or entry-declared scenarios."""
    if model_id in _STORES:
        return True
    entry = _REGISTRY.get(model_id)
    return bool(entry is not None and entry.scenarios)


def scenario_store(model_id: str, *, capacity: int = 4):
    """The id's ScenarioStore, bound to its resident cell (created — and
    seeded from ``ModelEntry.scenarios`` factories — on first use).

    One store per id per process, like the compiled cell it hangs off:
    every server for the id shares the same registered scenarios and
    LRU branch cache.  Re-registering the entry drops the store along
    with the cell.
    """
    with _LOCK:
        store = _STORES.get(model_id)
    if store is not None:
        return store
    from repro.scenario import ScenarioStore
    model, plan = compile_entry(model_id)
    store = ScenarioStore(model, plan, capacity=capacity)
    entry = resolve(model_id)
    for name, factory in entry.scenarios:
        store.register(name, branch=factory(model, plan))
    with _LOCK:
        return _STORES.setdefault(model_id, store)


def _builtin_entries():
    """The zoo: every smoke LM config plus the paper's CNN trunks."""
    out = []
    for arch in configs.ALL_ARCHS:
        out.append(ModelEntry(
            model_id=arch.replace("_", "-") + "-smoke",
            config=(lambda a=arch: configs.get_smoke(a))))
    from repro.models import cnn
    for name in ("vgg8", "resnet18", "darknet19", "tiny_yolo"):
        out.append(ModelEntry(
            model_id=name.replace("_", "-") + "-32",
            config=(lambda n=name: cnn.CNNConfig(name=n, input_size=32))))
    return out


for _e in _builtin_entries():
    register(_e)
del _e
