"""Continuous batching: interleave prefill and decode over one cell.

The scheduler owns requests.  Life of a request:

  submit -> admission queue (FIFO) -> [pool.try_admit: a row, and —
  paged — blocks for the whole request] solo prefill (batch=1,
  bit-identical to the standalone path; prompts longer than
  ``prefill_chunk`` run one chunk per tick, interleaved with decode) ->
  KV adopted into the pool (dense row copy or paged block scatter) ->
  joins the batched ``decode_step`` at the next step boundary ->
  retires when done (max_new_tokens or EOS) -> capacity freed, the
  rest of the batch keeps decoding.

Invariants (tested in tests/test_serve.py):
  * occupancy never exceeds the pool size;
  * admission is FIFO and work-conserving — a request waits only while
    the pool cannot guarantee it (rows, or paged block reservations)
    and admits as soon as it can (no starvation);
  * chunked prefill never stalls the batch: in-flight decodes advance
    on every tick a prefill chunk runs;
  * each request's tokens are bit-identical to a solo
    ``prefill`` + ``decode_step`` run of the same prompt, because the
    per-row attention cache (dense rows, or paged blocks gathered
    through the block table) makes batched decode row-independent.

Decoding is greedy (argmax) — deterministic, which is what makes the
bit-parity invariant testable end to end.

Speculative decode (``spec_k > 0``): the YOLoC-native draft/verify
split.  Each round, a cheap DRAFT model — the SRAM ReBranch branch with
the ROM trunk skipped (``CompiledModel.draft_decode_step``), or an
injected ``draft_source`` — proposes up to k tokens per row; then ONE
batched ``verify_step`` over the [N, k] block runs the full trunk+branch
cell and greedy accept-longest-prefix keeps the drafted prefix that
matches the verify argmaxes, plus the first mismatch's correction for
free.  Accepted output is bit-identical to non-speculative greedy decode
regardless of draft quality: position i's verify logits are computed
from the same accepted tokens plain decode would have fed, with drafted
future KV entries masked per query (see ``layers._verify_attention``).
Bookkeeping is kept symmetric by NOT claiming the bonus token a
fully-accepted block's last logits would give: both the verify cache and
the draft cache then always hold KV through the sequence's second-last
token, so every round starts with one uniform width-1 draft feed.
Rejected tails roll back through ``pool.rollback`` — lengths truncate
and (paged) tail blocks return to the free list with the row's
reservation re-credited, so speculation never leaks blocks.

Scenario hot-swap (repro.scenario): the batcher can swap the params
tree's SRAM branch over the resident ROM trunk mid-stream.  A swap is a
BARRIER in the same FIFO queue requests ride: it applies at a
decode-step boundary once every in-flight request has retired, so a
request admitted under scenario A decodes entirely under A — bit-
identical to a freshly compiled single-scenario cell — while requests
tagged for B wait behind the barrier.  The swap itself is one donated
combine (``scenario.swap_params``): trunk buffers alias through
untouched, zero ROM traffic, no recompile (the params tree structure is
unchanged, so the resident jit executables are reused as-is).
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.scenario import swap_params
from repro.serve.pool import SlotPool


@dataclasses.dataclass
class _Swap:
    """A scenario-swap barrier in the admission queue."""
    scenario: str
    branch: object                        # the new branch tree


@dataclasses.dataclass
class Request:
    """One user request plus its scheduling trace."""
    rid: int
    prompt: np.ndarray                    # [S] int32 token ids
    max_new_tokens: int
    eos_id: int | None = None
    scenario: str | None = None           # branch the request runs under
    # filled in by the scheduler:
    tokens: list = dataclasses.field(default_factory=list)
    slot: int | None = None
    submit_step: int = -1                 # scheduler tick at submit
    admit_step: int = -1                  # tick the prefill ran
    finish_step: int = -1                 # tick the last token landed
    submit_s: float = 0.0                 # wall clock, for latency stats
    finish_s: float = 0.0
    drafted: int = 0                      # draft tokens verified for this row
    matched: int = 0                      # of those, accepted (drafts only —
                                          # mismatch corrections not counted)

    @property
    def done(self) -> bool:
        return self.finish_step >= 0

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.submit_s


class ContinuousBatcher:
    """Admission queue + decode loop over one model and one KV pool.

    Works over either pool layout (dense :class:`~repro.serve.pool.
    SlotPool` or paged :class:`~repro.serve.pool.PagedPool`) through the
    shared ``try_admit`` / ``adopt`` / ``prepare_step`` / ``release``
    surface.

    ``prefill_chunk`` controls chunked prefill admission: a prompt
    longer than the chunk is prefilled one chunk per scheduler tick,
    interleaved with the batched decode steps, so admitting a long
    prompt never stalls in-flight decodes for its whole prefill.  The
    chunks run against the same solo (batch=1, dense) cache at their
    absolute positions, so the adopted row is bit-identical to a
    whole-prompt solo prefill (regression-tested).  ``None`` -> auto
    (32 for families that support it, see
    ``api.supports_chunked_prefill``); ``0`` -> whole-prompt admission.

    ``spec_k`` turns on speculative decode (see the module docstring):
    up to ``spec_k`` tokens drafted per row per round, one batched
    ``verify_step`` per round, accepted tokens bit-identical to plain
    greedy decode.  ``draft_source`` (optional) replaces the branch-only
    draft model with a callable ``(active: {slot: Request},
    last_tok: [n_slots, 1] int32, k) -> [n_slots, k] int32`` — used by
    benchmarks to dial acceptance rates deterministically; ``None``
    drafts through ``model.draft_decode_step`` over a dense draft KV
    cache that shadows the pool row for row.
    """

    def __init__(self, model, params, pool, *, scenario: str | None = None,
                 prefill_chunk: int | None = None, spec_k: int = 0,
                 draft_source=None):
        self.model = model
        self.params = params
        self.pool = pool
        self.scenario = scenario            # live branch label
        self.swap_count = 0                 # swaps applied so far
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if spec_k and not api.supports_speculation(model.cfg):
            raise ValueError(
                f"spec_k={spec_k} but {model.cfg.name!r} (family "
                f"{model.cfg.family!r}, sliding_window="
                f"{model.cfg.sliding_window}) cannot speculate: "
                f"rollback needs a full-horizon attention cache "
                f"(api.supports_speculation); pass spec_k=0")
        self.spec_k = int(spec_k)
        self.draft_source = draft_source
        self.spec_rounds = 0                # verify dispatches so far
        self.drafted_total = 0              # draft tokens verified
        self.matched_total = 0              # of those, accepted
        if self.spec_k:
            self._verify = jax.jit(model.verify_step, donate_argnums=(2,))
            if draft_source is None:
                # The draft model's own KV state: a dense cache with one
                # row per pool slot, indexed by the SAME slot ids (the
                # SlotPool here is a plain cache holder — its free list
                # is unused; admission/release stay with self.pool).
                self._draft_prefill = jax.jit(model.draft_prefill)
                self._draft_decode = jax.jit(model.draft_decode_step,
                                             donate_argnums=(2,))
                self._draft_pool = SlotPool(model, pool.n_slots,
                                            pool.max_len, dtype=pool.dtype)
        if prefill_chunk is None:
            prefill_chunk = 32 if api.supports_chunked_prefill(model.cfg) \
                else 0
        elif prefill_chunk and not api.supports_chunked_prefill(model.cfg):
            raise ValueError(
                f"prefill_chunk={prefill_chunk} but {model.cfg.name!r} "
                f"(family {model.cfg.family!r}) cannot chunk prefill — "
                f"ssm/hybrid recurrent state is rebuilt from position 0 "
                f"each prefill call; pass prefill_chunk=0")
        self.prefill_chunk = int(prefill_chunk)
        self._prefill = jax.jit(model.prefill)
        # donate the cache: the pool always replaces it with the returned
        # tree, so decode updates the KV rows in place instead of copying
        # the whole pool every step
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self._queue: collections.deque = collections.deque()
        self._active: dict[int, Request] = {}       # slot -> request
        # in-flight chunked prefill: (req, row, solo_cache, pos) or None
        self._prefilling: tuple | None = None
        # the token column fed to decode_step: one row per slot; free
        # rows carry 0 (their output is masked by never being read)
        self._tok = np.zeros((pool.n_slots, 1), np.int32)
        self._next_rid = 0
        self.step_count = 0

    # -- front door ------------------------------------------------------
    def pending_scenario(self) -> str | None:
        """The branch label after every queued swap barrier applies —
        what a submit() issued now will be admitted under."""
        for item in reversed(self._queue):
            if isinstance(item, _Swap):
                return item.scenario
        return self.scenario

    def swap(self, scenario: str | None, branch) -> None:
        """Queue a branch hot-swap.  FIFO with requests: everything
        submitted before the swap decodes under the old branch,
        everything after under the new one.  The swap applies at a
        decode-step boundary once the in-flight set has drained —
        in-flight requests always finish on their admitted scenario."""
        self._queue.append(_Swap(scenario=scenario, branch=branch))

    def submit(self, prompt, max_new_tokens: int,
               eos_id: int | None = None,
               scenario: str | None = None) -> Request:
        """Queue one request; returns its live :class:`Request` handle.

        Raises at the front door — never mid-decode — for requests that
        could never run: empty prompts, ``max_new_tokens < 1``, totals
        beyond the pool's horizon, and scenario labels that do not
        match the queue tail (swap first; ``LMServer.submit`` does)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        total = prompt.size + max_new_tokens
        if total > self.pool.max_len:
            raise ValueError(
                f"request needs {total} cache slots "
                f"(prompt {prompt.size} + {max_new_tokens} new) but the "
                f"pool was sized for max_len={self.pool.max_len}")
        tail = self.pending_scenario()
        if scenario is not None and scenario != tail:
            raise ValueError(
                f"submit(scenario={scenario!r}) but the queue tail runs "
                f"scenario {tail!r}; call swap({scenario!r}, branch) "
                f"first (LMServer.submit(..., scenario=...) does this "
                f"automatically via the scenario store)")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      scenario=tail)
        req.submit_step = self.step_count
        req.submit_s = time.perf_counter()
        self._next_rid += 1
        self._queue.append(req)
        return req

    # -- scheduler state -------------------------------------------------
    @property
    def queued(self) -> int:
        return sum(1 for x in self._queue if isinstance(x, Request))

    @property
    def active(self) -> int:
        return len(self._active)

    @property
    def prefilling(self) -> bool:
        """Whether a chunked prefill is in flight (its request is
        neither queued nor active: it holds a pool row but has not
        joined the decode batch)."""
        return self._prefilling is not None

    @property
    def idle(self) -> bool:
        return (not self._queue and not self._active
                and self._prefilling is None)

    @property
    def acceptance_rate(self) -> float:
        """Accepted / verified draft tokens over the batcher's lifetime
        (mismatch corrections — free tokens the verify computes itself —
        are not drafts and count in neither term)."""
        return (self.matched_total / self.drafted_total
                if self.drafted_total else 0.0)

    # -- the loop ----------------------------------------------------------
    def _finish(self, req: Request) -> None:
        req.finish_step = self.step_count
        req.finish_s = time.perf_counter()
        self.pool.release(req.slot)
        del self._active[req.slot]

    def _maybe_retire(self, req: Request) -> None:
        hit_eos = (req.eos_id is not None and req.tokens
                   and req.tokens[-1] == req.eos_id)
        if len(req.tokens) >= req.max_new_tokens or hit_eos:
            self._finish(req)

    def _apply_swap(self, sw: _Swap) -> None:
        """One donated combine: branch leaves replaced, trunk buffers
        alias through in place (zero ROM traffic, no recompile — the
        tree structure is unchanged so the jitted prefill/decode
        executables are reused as-is)."""
        self.params = swap_params(self.params, sw.branch)
        self.scenario = sw.scenario
        self.swap_count += 1

    def _activate(self, req: Request, slot: int, solo, logits) -> None:
        """Adopt a finished solo prefill into the pool and put the
        request into the decode batch (its first token comes from the
        prefill logits, exactly like the standalone path)."""
        self.pool.adopt(slot, solo)
        if self.spec_k and self.draft_source is not None:
            pass                          # injected drafter: no draft KV
        elif self.spec_k:
            # Shadow the row in the draft model's cache: one whole-prompt
            # branch-only prefill (cheap — the trunks are skipped), so
            # the draft cache holds KV for the prompt and starts every
            # round one token behind the sequence tail, exactly like the
            # verify cache.  Chunking is unnecessary at draft cost.
            d_solo = self._draft_pool.solo_cache()
            _, d_solo = self._draft_prefill(
                self.params, {"tokens": jnp.asarray(req.prompt[None])},
                d_solo)
            self._draft_pool.adopt(slot, d_solo)
        first = int(jnp.argmax(logits[0, -1]))
        req.slot = slot
        req.admit_step = self.step_count
        req.tokens.append(first)
        self._tok[slot, 0] = first
        self._active[slot] = req
        self._maybe_retire(req)           # 1-token requests finish here

    def _advance_prefill(self) -> None:
        """Run ONE chunk of the in-flight prefill.  Each chunk extends
        the same solo cache at its absolute offset, so the finished row
        is bit-identical to a whole-prompt solo prefill; the final
        chunk's logits yield the first token and the row activates."""
        req, slot, solo, pos = self._prefilling
        end = min(pos + self.prefill_chunk, req.prompt.size)
        logits, solo = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompt[None, pos:end])},
            solo)
        if end < req.prompt.size:
            self._prefilling = (req, slot, solo, end)
        else:
            self._prefilling = None
            self._activate(req, slot, solo, logits)

    def _admit(self) -> None:
        """FIFO admission against the pool's capacity.

        The head request admits only when the pool can GUARANTEE it
        (``try_admit``: a free row, and — paged — enough unreserved
        blocks for prompt + max_new_tokens); admission stays strictly
        FIFO, so a big request blocks the queue rather than starving.
        Prompts longer than ``prefill_chunk`` prefill one chunk per
        tick (at most one such prefill in flight; decode keeps running
        between chunks).  A queued _Swap barrier applies only once
        in-flight work has drained — active rows AND any chunked
        prefill, which must finish under the params it started with."""
        if self._prefilling is not None:
            self._advance_prefill()
            if self._prefilling is not None:
                return            # still mid-prompt; FIFO order holds
        while self._queue:
            head = self._queue[0]
            if isinstance(head, _Swap):
                if self._active or self._prefilling is not None:
                    return        # in-flight work finishes on its branch
                self._apply_swap(self._queue.popleft())
                continue
            slot = self.pool.try_admit(head.prompt.size
                                       + head.max_new_tokens)
            if slot is None:
                return            # work-conserving: wait for capacity
            req = self._queue.popleft()
            solo = self.pool.solo_cache()
            if self.prefill_chunk and req.prompt.size > self.prefill_chunk:
                self._prefilling = (req, slot, solo, 0)
                self._advance_prefill()       # first chunk, this tick
                if self._prefilling is not None:
                    return
                continue
            logits, solo = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt[None])},
                solo)
            self._activate(req, slot, solo, logits)

    def step(self) -> bool:
        """One scheduler tick: retire / admit at the boundary (one
        prefill chunk at most), then one batched decode step — or, in
        speculative mode, one draft+verify round.  Returns False once
        idle."""
        self._admit()
        if not self._active:
            return not self.idle
        if self.spec_k:
            return self._spec_step()
        # paged pools grant each row's next block here; dense no-op
        self.pool.prepare_step()
        logits, cache = self._decode(
            self.params, jnp.asarray(self._tok), self.pool.cache)
        self.pool.cache = cache
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        self.step_count += 1
        for slot, req in list(self._active.items()):
            req.tokens.append(int(nxt[slot]))
            self._tok[slot, 0] = nxt[slot]
            self._maybe_retire(req)
        return not self.idle

    def _spec_step(self) -> bool:
        """One draft+verify round over the active batch.

        k is clamped to the smallest remaining token budget across
        active rows: every row then needs at most k more cache
        positions, which its admission already reserved — verify writes
        can never wrap or outrun the pool.  The round: k width-1 draft
        feeds propose d[0..k-1]; verify runs the [N, k] block
        [last_token, d[0..k-2]] through the full cell; row-wise, the
        longest drafted prefix matching the verify argmaxes is accepted
        plus the first mismatch's correction (so every round lands 1..k
        tokens, and a k=1 round IS a plain decode step, bit for bit).
        Rejected tails roll back — verify cache AND draft cache — to
        the accepted length.
        """
        k = min(self.spec_k,
                min(r.max_new_tokens - len(r.tokens)
                    for r in self._active.values()))
        n = self.pool.n_slots
        if self.draft_source is not None:
            drafts = np.asarray(
                self.draft_source(dict(self._active), self._tok.copy(), k),
                np.int32).reshape(n, k)
        else:
            drafts = np.zeros((n, k), np.int32)
            tok = self._tok
            for j in range(k):
                d_logits, d_cache = self._draft_decode(
                    self.params, jnp.asarray(tok), self._draft_pool.cache)
                self._draft_pool.cache = d_cache
                nxt = np.asarray(jnp.argmax(d_logits[:, -1, :], axis=-1),
                                 np.int32)
                drafts[:, j] = nxt
                tok = nxt[:, None]
        # one batched verify over [last_token, d0..d_{k-2}]
        block = np.concatenate([self._tok, drafts[:, :k - 1]], axis=1)
        self.pool.prepare_tokens(k)
        logits, cache = self._verify(
            self.params, jnp.asarray(block), self.pool.cache)
        self.pool.cache = cache
        truth = np.asarray(jnp.argmax(logits, axis=-1), np.int32)  # [N, k]
        self.step_count += 1
        self.spec_rounds += 1
        roll: dict[int, int] = {}
        for slot, req in list(self._active.items()):
            d, c = drafts[slot], truth[slot]
            j = int(np.argmax(d != c)) if bool((d != c).any()) else k
            accepted = [int(t) for t in c[:min(j + 1, k)]]
            req.drafted += k
            req.matched += j if j < k else k
            self.drafted_total += k
            self.matched_total += j if j < k else k
            old_len = req.prompt.size + len(req.tokens) - 1
            for t in accepted:
                req.tokens.append(t)
                if req.eos_id is not None and t == req.eos_id:
                    break                 # EOS mid-block: drop the rest
            self._tok[slot, 0] = req.tokens[-1]
            new_len = req.prompt.size + len(req.tokens) - 1
            self._maybe_retire(req)       # retirement releases the row:
            if slot in self._active and new_len != old_len + k:
                roll[slot] = new_len      # survivors truncate the tail
        self.pool.rollback(roll)
        if self.draft_source is None:
            self._draft_pool.rollback(roll)
        return not self.idle

    def drain(self, max_steps: int | None = None) -> int:
        """Run until every submitted request finished; returns the
        number of decode steps taken.  ``max_steps`` guards tests
        against scheduler bugs (raises instead of spinning)."""
        start = self.step_count
        while not self.idle:
            if max_steps is not None and \
                    self.step_count - start >= max_steps:
                raise RuntimeError(
                    f"drain() exceeded {max_steps} steps with "
                    f"{self.queued} queued / {self.active} active — "
                    f"scheduler stuck?")
            self.step()
        return self.step_count - start
