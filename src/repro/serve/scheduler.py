"""Continuous batching: interleave prefill and decode over one cell.

The scheduler owns requests.  Life of a request:

  submit -> admission queue (FIFO) -> [pool.try_admit: a row, and —
  paged — blocks for the whole request] solo prefill (batch=1,
  bit-identical to the standalone path; prompts longer than
  ``prefill_chunk`` run one chunk per tick, interleaved with decode) ->
  KV adopted into the pool (dense row copy or paged block scatter) ->
  joins the batched ``decode_step`` at the next step boundary ->
  retires when done (max_new_tokens or EOS) -> capacity freed, the
  rest of the batch keeps decoding.

Invariants (tested in tests/test_serve.py):
  * occupancy never exceeds the pool size;
  * admission is FIFO and work-conserving — a request waits only while
    the pool cannot guarantee it (rows, or paged block reservations)
    and admits as soon as it can (no starvation);
  * chunked prefill never stalls the batch: in-flight decodes advance
    on every tick a prefill chunk runs;
  * each request's tokens are bit-identical to a solo
    ``prefill`` + ``decode_step`` run of the same prompt, because the
    per-row attention cache (dense rows, or paged blocks gathered
    through the block table) makes batched decode row-independent.

Decoding is greedy (argmax) — deterministic, which is what makes the
bit-parity invariant testable end to end.

Scenario hot-swap (repro.scenario): the batcher can swap the params
tree's SRAM branch over the resident ROM trunk mid-stream.  A swap is a
BARRIER in the same FIFO queue requests ride: it applies at a
decode-step boundary once every in-flight request has retired, so a
request admitted under scenario A decodes entirely under A — bit-
identical to a freshly compiled single-scenario cell — while requests
tagged for B wait behind the barrier.  The swap itself is one donated
combine (``scenario.swap_params``): trunk buffers alias through
untouched, zero ROM traffic, no recompile (the params tree structure is
unchanged, so the resident jit executables are reused as-is).
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.scenario import swap_params


@dataclasses.dataclass
class _Swap:
    """A scenario-swap barrier in the admission queue."""
    scenario: str
    branch: object                        # the new branch tree


@dataclasses.dataclass
class Request:
    """One user request plus its scheduling trace."""
    rid: int
    prompt: np.ndarray                    # [S] int32 token ids
    max_new_tokens: int
    eos_id: int | None = None
    scenario: str | None = None           # branch the request runs under
    # filled in by the scheduler:
    tokens: list = dataclasses.field(default_factory=list)
    slot: int | None = None
    submit_step: int = -1                 # scheduler tick at submit
    admit_step: int = -1                  # tick the prefill ran
    finish_step: int = -1                 # tick the last token landed
    submit_s: float = 0.0                 # wall clock, for latency stats
    finish_s: float = 0.0

    @property
    def done(self) -> bool:
        return self.finish_step >= 0

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.submit_s


class ContinuousBatcher:
    """Admission queue + decode loop over one model and one KV pool.

    Works over either pool layout (dense :class:`~repro.serve.pool.
    SlotPool` or paged :class:`~repro.serve.pool.PagedPool`) through the
    shared ``try_admit`` / ``adopt`` / ``prepare_step`` / ``release``
    surface.

    ``prefill_chunk`` controls chunked prefill admission: a prompt
    longer than the chunk is prefilled one chunk per scheduler tick,
    interleaved with the batched decode steps, so admitting a long
    prompt never stalls in-flight decodes for its whole prefill.  The
    chunks run against the same solo (batch=1, dense) cache at their
    absolute positions, so the adopted row is bit-identical to a
    whole-prompt solo prefill (regression-tested).  ``None`` -> auto
    (32 for families that support it, see
    ``api.supports_chunked_prefill``); ``0`` -> whole-prompt admission.
    """

    def __init__(self, model, params, pool, *, scenario: str | None = None,
                 prefill_chunk: int | None = None):
        self.model = model
        self.params = params
        self.pool = pool
        self.scenario = scenario            # live branch label
        self.swap_count = 0                 # swaps applied so far
        if prefill_chunk is None:
            prefill_chunk = 32 if api.supports_chunked_prefill(model.cfg) \
                else 0
        elif prefill_chunk and not api.supports_chunked_prefill(model.cfg):
            raise ValueError(
                f"prefill_chunk={prefill_chunk} but {model.cfg.name!r} "
                f"(family {model.cfg.family!r}) cannot chunk prefill — "
                f"ssm/hybrid recurrent state is rebuilt from position 0 "
                f"each prefill call; pass prefill_chunk=0")
        self.prefill_chunk = int(prefill_chunk)
        self._prefill = jax.jit(model.prefill)
        # donate the cache: the pool always replaces it with the returned
        # tree, so decode updates the KV rows in place instead of copying
        # the whole pool every step
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self._queue: collections.deque = collections.deque()
        self._active: dict[int, Request] = {}       # slot -> request
        # in-flight chunked prefill: (req, row, solo_cache, pos) or None
        self._prefilling: tuple | None = None
        # the token column fed to decode_step: one row per slot; free
        # rows carry 0 (their output is masked by never being read)
        self._tok = np.zeros((pool.n_slots, 1), np.int32)
        self._next_rid = 0
        self.step_count = 0

    # -- front door ------------------------------------------------------
    def pending_scenario(self) -> str | None:
        """The branch label after every queued swap barrier applies —
        what a submit() issued now will be admitted under."""
        for item in reversed(self._queue):
            if isinstance(item, _Swap):
                return item.scenario
        return self.scenario

    def swap(self, scenario: str | None, branch) -> None:
        """Queue a branch hot-swap.  FIFO with requests: everything
        submitted before the swap decodes under the old branch,
        everything after under the new one.  The swap applies at a
        decode-step boundary once the in-flight set has drained —
        in-flight requests always finish on their admitted scenario."""
        self._queue.append(_Swap(scenario=scenario, branch=branch))

    def submit(self, prompt, max_new_tokens: int,
               eos_id: int | None = None,
               scenario: str | None = None) -> Request:
        """Queue one request; returns its live :class:`Request` handle.

        Raises at the front door — never mid-decode — for requests that
        could never run: empty prompts, ``max_new_tokens < 1``, totals
        beyond the pool's horizon, and scenario labels that do not
        match the queue tail (swap first; ``LMServer.submit`` does)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        total = prompt.size + max_new_tokens
        if total > self.pool.max_len:
            raise ValueError(
                f"request needs {total} cache slots "
                f"(prompt {prompt.size} + {max_new_tokens} new) but the "
                f"pool was sized for max_len={self.pool.max_len}")
        tail = self.pending_scenario()
        if scenario is not None and scenario != tail:
            raise ValueError(
                f"submit(scenario={scenario!r}) but the queue tail runs "
                f"scenario {tail!r}; call swap({scenario!r}, branch) "
                f"first (LMServer.submit(..., scenario=...) does this "
                f"automatically via the scenario store)")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      scenario=tail)
        req.submit_step = self.step_count
        req.submit_s = time.perf_counter()
        self._next_rid += 1
        self._queue.append(req)
        return req

    # -- scheduler state -------------------------------------------------
    @property
    def queued(self) -> int:
        return sum(1 for x in self._queue if isinstance(x, Request))

    @property
    def active(self) -> int:
        return len(self._active)

    @property
    def prefilling(self) -> bool:
        """Whether a chunked prefill is in flight (its request is
        neither queued nor active: it holds a pool row but has not
        joined the decode batch)."""
        return self._prefilling is not None

    @property
    def idle(self) -> bool:
        return (not self._queue and not self._active
                and self._prefilling is None)

    # -- the loop ----------------------------------------------------------
    def _finish(self, req: Request) -> None:
        req.finish_step = self.step_count
        req.finish_s = time.perf_counter()
        self.pool.release(req.slot)
        del self._active[req.slot]

    def _maybe_retire(self, req: Request) -> None:
        hit_eos = (req.eos_id is not None and req.tokens
                   and req.tokens[-1] == req.eos_id)
        if len(req.tokens) >= req.max_new_tokens or hit_eos:
            self._finish(req)

    def _apply_swap(self, sw: _Swap) -> None:
        """One donated combine: branch leaves replaced, trunk buffers
        alias through in place (zero ROM traffic, no recompile — the
        tree structure is unchanged so the jitted prefill/decode
        executables are reused as-is)."""
        self.params = swap_params(self.params, sw.branch)
        self.scenario = sw.scenario
        self.swap_count += 1

    def _activate(self, req: Request, slot: int, solo, logits) -> None:
        """Adopt a finished solo prefill into the pool and put the
        request into the decode batch (its first token comes from the
        prefill logits, exactly like the standalone path)."""
        self.pool.adopt(slot, solo)
        first = int(jnp.argmax(logits[0, -1]))
        req.slot = slot
        req.admit_step = self.step_count
        req.tokens.append(first)
        self._tok[slot, 0] = first
        self._active[slot] = req
        self._maybe_retire(req)           # 1-token requests finish here

    def _advance_prefill(self) -> None:
        """Run ONE chunk of the in-flight prefill.  Each chunk extends
        the same solo cache at its absolute offset, so the finished row
        is bit-identical to a whole-prompt solo prefill; the final
        chunk's logits yield the first token and the row activates."""
        req, slot, solo, pos = self._prefilling
        end = min(pos + self.prefill_chunk, req.prompt.size)
        logits, solo = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompt[None, pos:end])},
            solo)
        if end < req.prompt.size:
            self._prefilling = (req, slot, solo, end)
        else:
            self._prefilling = None
            self._activate(req, slot, solo, logits)

    def _admit(self) -> None:
        """FIFO admission against the pool's capacity.

        The head request admits only when the pool can GUARANTEE it
        (``try_admit``: a free row, and — paged — enough unreserved
        blocks for prompt + max_new_tokens); admission stays strictly
        FIFO, so a big request blocks the queue rather than starving.
        Prompts longer than ``prefill_chunk`` prefill one chunk per
        tick (at most one such prefill in flight; decode keeps running
        between chunks).  A queued _Swap barrier applies only once
        in-flight work has drained — active rows AND any chunked
        prefill, which must finish under the params it started with."""
        if self._prefilling is not None:
            self._advance_prefill()
            if self._prefilling is not None:
                return            # still mid-prompt; FIFO order holds
        while self._queue:
            head = self._queue[0]
            if isinstance(head, _Swap):
                if self._active or self._prefilling is not None:
                    return        # in-flight work finishes on its branch
                self._apply_swap(self._queue.popleft())
                continue
            slot = self.pool.try_admit(head.prompt.size
                                       + head.max_new_tokens)
            if slot is None:
                return            # work-conserving: wait for capacity
            req = self._queue.popleft()
            solo = self.pool.solo_cache()
            if self.prefill_chunk and req.prompt.size > self.prefill_chunk:
                self._prefilling = (req, slot, solo, 0)
                self._advance_prefill()       # first chunk, this tick
                if self._prefilling is not None:
                    return
                continue
            logits, solo = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt[None])},
                solo)
            self._activate(req, slot, solo, logits)

    def step(self) -> bool:
        """One scheduler tick: retire / admit at the boundary (one
        prefill chunk at most), then one batched decode step.  Returns
        False once idle."""
        self._admit()
        if not self._active:
            return not self.idle
        # paged pools grant each row's next block here; dense no-op
        self.pool.prepare_step()
        logits, cache = self._decode(
            self.params, jnp.asarray(self._tok), self.pool.cache)
        self.pool.cache = cache
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        self.step_count += 1
        for slot, req in list(self._active.items()):
            req.tokens.append(int(nxt[slot]))
            self._tok[slot, 0] = nxt[slot]
            self._maybe_retire(req)
        return not self.idle

    def drain(self, max_steps: int | None = None) -> int:
        """Run until every submitted request finished; returns the
        number of decode steps taken.  ``max_steps`` guards tests
        against scheduler bugs (raises instead of spinning)."""
        start = self.step_count
        while not self.idle:
            if max_steps is not None and \
                    self.step_count - start >= max_steps:
                raise RuntimeError(
                    f"drain() exceeded {max_steps} steps with "
                    f"{self.queued} queued / {self.active} active — "
                    f"scheduler stuck?")
            self.step()
        return self.step_count - start
