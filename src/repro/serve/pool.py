"""KV-cache pools: one resident cache, capacity owned by requests.

Two layouts behind one scheduler-facing interface (``try_admit`` /
``adopt`` / ``prepare_step`` / ``release``):

:class:`SlotPool` — dense.  One ``init_cache(n_slots, max_len)`` tree;
each request owns one full-horizon batch row for its lifetime, so a
16-token prompt pays the same bytes as a full-horizon one.

:class:`PagedPool` — paged.  The same byte budget carved into
fixed-size physical blocks shared by every row: each request holds a
block TABLE (logical block -> physical block), blocks are reserved at
admission but granted on demand as decode advances, and short requests
only ever pin the blocks they actually fill.  The attention math is
unchanged — ``models.layers`` gathers the logical view through the
table, bit-identical to the dense row at every valid position — so the
serving invariant (batched tokens == solo tokens, bitwise) holds across
both layouts.

Admission copies a solo-prefilled (batch=1, dense) cache into the
request's row/blocks — bitwise, no rescale — so a request's decode
continues from exactly the state the solo path would hold.  Retirement
just returns the capacity: stale rows/blocks are dead weight until the
next adoption overwrites them (decode may keep writing garbage for free
rows; nothing reads it because every row's validity mask follows its
own ``length``, and a paged free row's writes land in the reserved
trash block).

Pool sizing comes from the :class:`~repro.plan.PlacementPlan`'s SRAM
residency stats: the branch cores and any SRAM-resident sites already
occupy on-die SRAM, and the KV capacity lives in what remains of the
activation budget (:func:`suggest_slots` / :func:`suggest_paged`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api


def _batch_axis(cfg) -> int:
    """Batch axis of every cache leaf: 1 under scan-stacked layers
    (leaves carry a leading L dim), 0 otherwise."""
    return 1 if getattr(cfg, "scan_layers", False) else 0


def _set_lengths(cache, new_lens: dict[int, int], scan: bool):
    """Scatter per-row ``length`` values into every layer of a serve
    cache (shared by both pools' speculative ``rollback``)."""
    rows = jnp.asarray(sorted(new_lens), jnp.int32)
    vals = jnp.asarray([new_lens[r] for r in sorted(new_lens)], jnp.int32)
    layers = cache["layers"]
    if scan:       # stacked leaves: [L, B] lengths, broadcast over L
        layers = {**layers, "length": layers["length"].at[:, rows]
                  .set(vals[None])}
        return {"layers": layers}
    return {"layers": [{**ld, "length": ld["length"].at[rows].set(vals)}
                       for ld in layers]}


class SlotPool:
    """N cache rows + a free list; adoption and release are O(1)."""

    def __init__(self, model, n_slots: int, max_len: int,
                 dtype=jnp.float32):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.model = model
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.dtype = dtype
        self._axis = _batch_axis(model.cfg)
        self.cache = model.init_cache(n_slots, max_len, dtype=dtype)
        self._free = list(range(n_slots))[::-1]     # pop() -> slot 0 first

    # -- bookkeeping ----------------------------------------------------
    @property
    def free_slots(self) -> int:
        """Rows not currently owned by a request."""
        return len(self._free)

    @property
    def occupancy(self) -> int:
        """Rows currently owned by requests (never exceeds n_slots)."""
        return self.n_slots - len(self._free)

    def alloc(self) -> int | None:
        """Pop a free slot, or ``None`` when every row is held."""
        return self._free.pop() if self._free else None

    def try_admit(self, total_len: int) -> int | None:
        """Claim capacity for a request needing ``total_len`` positions.

        Dense rows always span the full horizon, so the only resource is
        the row itself: returns a slot or ``None`` (no starvation state
        to track).  Raises if ``total_len`` exceeds the pool horizon —
        the request could never fit, waiting won't help.
        """
        if total_len > self.max_len:
            raise ValueError(
                f"request needs {total_len} cache positions but the pool "
                f"was sized for max_len={self.max_len}")
        return self.alloc()

    def release(self, slot: int) -> None:
        """Return a slot to the free list.  Raises on out-of-range and
        double-release (both indicate scheduler bookkeeping bugs)."""
        if not (0 <= slot < self.n_slots):
            raise ValueError(f"slot {slot} outside pool of {self.n_slots}")
        if slot in self._free:
            raise ValueError(f"slot {slot} double-released")
        self._free.append(slot)

    def prepare_step(self) -> None:
        """Pre-decode hook: dense rows never need new capacity (no-op;
        the paged pool grants blocks here)."""

    def prepare_tokens(self, n: int) -> None:
        """Pre-verify hook for an ``n``-token speculative block: dense
        rows span the full horizon, nothing to grant (no-op; the paged
        pool grants the covering blocks here)."""

    def rollback(self, new_lens: dict[int, int]) -> None:
        """Truncate rows to ``{slot: new_length}`` after a speculative
        verify rejected part of a draft block.  Dense rows only need
        their device lengths reset — the rejected KV entries beyond the
        new length become stale garbage that the validity mask hides
        until the next write overwrites them (exactly like a retired
        row's leftovers)."""
        if not new_lens:
            return
        self.cache = _set_lengths(self.cache, new_lens, self._axis == 1)

    # -- cache row transfer ---------------------------------------------
    def adopt(self, slot: int, solo_cache) -> None:
        """Copy a batch=1 cache into ``slot``'s row, leaf by leaf."""
        axis = self._axis

        def put(pool_leaf, solo_leaf):
            row = jax.lax.index_in_dim(solo_leaf, 0, axis, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(
                pool_leaf, row.astype(pool_leaf.dtype), slot, axis)

        self.cache = jax.tree.map(put, self.cache, solo_cache)

    def solo_cache(self):
        """A fresh batch=1 cache with this pool's geometry (for the
        admission prefill; same max_len so adopted rows line up)."""
        return self.model.init_cache(1, self.max_len, dtype=self.dtype)


class PagedPool:
    """Paged KV pool: shared physical blocks, per-request block tables.

    The cache tree holds ``n_blocks + 1`` physical blocks of
    ``block_size`` positions per layer (the extra one is the TRASH
    block, see below) plus a ``[n_rows, max_len/block_size]`` block
    table.  A request's life:

      ``try_admit(total)`` reserves ``ceil(total/block_size)`` blocks
      (and a table row) without touching the device — admission is
      refused unless the whole request is guaranteed to fit, so decode
      can never deadlock on a block that will never free.
      ``adopt(row, solo_cache)`` grants the blocks covering the
      prefilled prompt and scatters the dense solo row into them,
      bitwise.  ``prepare_step()`` (called by the scheduler before
      every batched decode) grants each active row the block holding
      its next write position — on-demand growth, so a request that
      retires early (EOS) never materialises its reservation's tail.
      ``release(row)`` frees the blocks and points the row's table back
      at the trash block.

    The trash block: decode writes one KV entry for EVERY batch row,
    including free rows (their output is masked, never read).  Free
    rows' table entries all point at the last physical block, so those
    garbage writes can never land inside a live request's blocks.

    Error behavior matches the geometry-error style of ``deploy.py``:
    impossible requests (``total > max_len``) raise at admission;
    double-release and foreign rows raise; a grant with no free block
    raises RuntimeError naming the reservation invariant that would
    have to be broken for it to happen.
    """

    def __init__(self, model, n_rows: int, n_blocks: int,
                 block_size: int, max_len: int, dtype=jnp.float32):
        if n_rows < 1:
            raise ValueError(f"need at least one row, got {n_rows}")
        if max_len % block_size:
            raise ValueError(
                f"block_size {block_size} does not divide max_len "
                f"{max_len} (the logical view must match the dense "
                f"cache geometry exactly)")
        if n_blocks < max_len // block_size:
            raise ValueError(
                f"{n_blocks} blocks of {block_size} cannot hold even "
                f"one full-horizon request (max_len {max_len} needs "
                f"{max_len // block_size}); shrink max_len or grow the "
                f"pool")
        self.model = model
        self.n_rows = int(n_rows)
        self.n_blocks = int(n_blocks)          # usable (trash excluded)
        self.block_size = int(block_size)
        self.max_len = int(max_len)
        self.dtype = dtype
        self._axis = _batch_axis(model.cfg)
        self._scan = self._axis == 1
        self.nb_logical = max_len // block_size
        # +1: the last physical block is the trash block for free rows
        self.cache = model.init_paged_cache(
            n_rows, n_blocks + 1, block_size, max_len, dtype=dtype)
        self._trash = n_blocks
        self._table = np.full((n_rows, self.nb_logical), self._trash,
                              np.int32)
        self._free_rows = list(range(n_rows))[::-1]   # pop() -> row 0 first
        self._free_blocks = list(range(n_blocks))[::-1]
        self._owed: dict[int, int] = {}      # row -> reserved, not granted
        self._blocks: dict[int, list[int]] = {}   # row -> granted physical
        self._len: dict[int, int] = {}       # row -> next write position
        self._dirty = True                   # host table ahead of device

    # -- bookkeeping ----------------------------------------------------
    @property
    def n_slots(self) -> int:
        """Batch-row count (scheduler-facing alias: the decode batch is
        one token column per row, same as the dense pool)."""
        return self.n_rows

    @property
    def free_slots(self) -> int:
        return len(self._free_rows)

    @property
    def occupancy(self) -> int:
        return self.n_rows - len(self._free_rows)

    @property
    def blocks_in_use(self) -> int:
        """Physical blocks granted to live requests (excludes
        reservations not yet materialised and the trash block)."""
        return sum(len(b) for b in self._blocks.values())

    @property
    def blocks_reserved(self) -> int:
        """Blocks promised at admission but not yet granted — held back
        from new admissions so in-flight decodes can always grow."""
        return sum(self._owed.values())

    @property
    def live_tokens(self) -> int:
        """Cache positions actually holding live KV entries."""
        return sum(self._len.values())

    @property
    def utilization(self) -> float:
        """live_tokens / granted capacity — 1.0 means zero internal
        fragmentation (every granted block position holds a live KV)."""
        used = self.blocks_in_use * self.block_size
        return self.live_tokens / used if used else 0.0

    # -- admission -------------------------------------------------------
    def try_admit(self, total_len: int) -> int | None:
        """Reserve a row + enough blocks for a ``total_len``-position
        request; returns the row, or ``None`` when the pool cannot
        GUARANTEE the request completes (no free row, or too few
        unreserved blocks).  Conservative by design: over-admitting
        would deadlock decode mid-request on an empty free list.
        Raises if ``total_len`` exceeds the logical horizon (the
        request could never fit; waiting won't help)."""
        if total_len > self.max_len:
            raise ValueError(
                f"request needs {total_len} cache positions but the "
                f"pool's logical horizon is max_len={self.max_len}")
        if not self._free_rows:
            return None
        need = -(-total_len // self.block_size)
        if need > len(self._free_blocks) - self.blocks_reserved:
            return None
        row = self._free_rows.pop()
        self._owed[row] = need
        self._blocks[row] = []
        # NOT in self._len yet: the row joins the decode batch (and
        # prepare_step's grant/advance loop) only at adopt() — between
        # try_admit and adopt its table points at the trash block and
        # its masked decode writes are garbage by design.
        return row

    def _grant(self, row: int) -> None:
        """Materialise one reserved block as ``row``'s next logical
        block (host-side; ``sync`` pushes the table to the device)."""
        if not self._free_blocks:
            raise RuntimeError(
                "no free block for a granted reservation — the "
                "try_admit invariant (reserved <= free) was broken")
        blk = self._free_blocks.pop()
        idx = len(self._blocks[row])
        self._blocks[row].append(blk)
        self._owed[row] = max(0, self._owed[row] - 1)
        self._table[row, idx] = blk
        self._dirty = True

    def prepare_step(self) -> None:
        """Grant every active row the block holding its next write
        position, advance the host-side lengths, and sync the table.
        The scheduler calls this immediately before each batched
        ``decode_step`` — after it returns, no in-flight write can miss
        its block."""
        self.prepare_tokens(1)

    def prepare_tokens(self, n: int) -> None:
        """Multi-token ``prepare_step``: grant every active row the
        blocks covering its next ``n`` write positions (a speculative
        verify writes a whole k-token block per row) and advance the
        host-side lengths by ``n``.  Grants stay within the admission
        reservation — the scheduler clamps k so a row never speculates
        past its admitted ``prompt + max_new_tokens`` need — and
        ``rollback`` returns whatever a rejected draft leaves unused."""
        if n < 1:
            raise ValueError(f"need at least one token, got {n}")
        for row in self._len:
            pos = self._len[row]
            while (pos + n - 1) // self.block_size >= \
                    len(self._blocks[row]):
                self._grant(row)
            self._len[row] = pos + n
        self.sync()

    def rollback(self, new_lens: dict[int, int]) -> None:
        """Truncate rows to ``{row: new_length}`` after a speculative
        verify rejected part of a draft block.

        Three things must round-trip, or speculation would leak:
          * device lengths reset, so the validity mask hides the
            rejected entries (they are overwritten before ever being
            readable again — the next block's writes start at
            ``new_length``);
          * tail blocks past ``ceil(new_length/block_size)`` return to
            the free list AND re-credit the row's reservation
            (``_owed``), keeping the admission invariant — granted +
            owed always covers the row's remaining worst case, and
            ``free - reserved`` seen by ``try_admit`` is exactly what
            it was before the speculative grant;
          * the table tail points back at the trash block, so the
            row's future masked writes can't land in blocks that may
            be re-granted to someone else.
        """
        if not new_lens:
            return
        for row, new_len in new_lens.items():
            if row not in self._blocks:
                raise ValueError(
                    f"rollback of row {row}, which holds no blocks "
                    f"(released, or never admitted)")
            if not (0 <= new_len <= self._len.get(row, 0)):
                raise ValueError(
                    f"rollback of row {row} to length {new_len}, "
                    f"outside [0, {self._len.get(row, 0)}] — rollback "
                    f"only ever truncates")
            keep = -(-new_len // self.block_size)
            tail = self._blocks[row][keep:]
            if tail:
                del self._blocks[row][keep:]
                self._free_blocks.extend(reversed(tail))
                self._owed[row] = self._owed.get(row, 0) + len(tail)
                self._table[row, keep:] = self._trash
                self._dirty = True
            self._len[row] = new_len
        self.cache = _set_lengths(self.cache, new_lens, self._scan)
        self.sync()

    def release(self, row: int) -> None:
        """Free a row: blocks return to the free list, the table row
        points back at the trash block (so the freed row's masked
        decode writes stop landing in blocks about to be re-granted)."""
        if not (0 <= row < self.n_rows):
            raise ValueError(f"row {row} outside pool of {self.n_rows}")
        if row not in self._blocks:
            raise ValueError(f"row {row} double-released")
        self._free_blocks.extend(reversed(self._blocks.pop(row)))
        self._owed.pop(row, None)
        self._len.pop(row, None)
        self._table[row, :] = self._trash
        self._dirty = True
        self._free_rows.append(row)

    # -- cache transfer --------------------------------------------------
    def solo_cache(self):
        """A fresh DENSE batch=1 cache at this pool's logical horizon —
        prefill cannot run against paged state (see
        ``layers.apply_attention``); adoption scatters the dense row
        into blocks."""
        return self.model.init_cache(1, self.max_len, dtype=self.dtype)

    def adopt(self, row: int, solo_cache) -> None:
        """Grant the blocks covering the solo-prefilled prompt and
        scatter its dense KV row into them, bitwise (one scatter per
        leaf).  The row's device length is set from the solo cache, so
        decode continues exactly where the solo path stood."""
        if row not in self._blocks:
            raise ValueError(
                f"row {row} was not admitted (call try_admit first)")
        first = api._first_layer(solo_cache)
        length = int(np.asarray(first["length"]).reshape(-1)[0])
        n_grant = -(-length // self.block_size)
        while len(self._blocks[row]) < n_grant:
            self._grant(row)
        phys = jnp.asarray(self._blocks[row][:n_grant], jnp.int32)
        span = n_grant * self.block_size
        bs = self.block_size

        def put(pool_layer, solo_layer):
            out = dict(pool_layer)
            for key in ("k", "v"):
                pl, sl = pool_layer[key], solo_layer[key]
                if self._scan:   # [L,P,bs,KV,Dh] <- [L,1,max_len,KV,Dh]
                    blocks = sl[:, 0, :span].reshape(
                        sl.shape[0], n_grant, bs, *sl.shape[3:])
                    out[key] = pl.at[:, phys].set(blocks.astype(pl.dtype))
                else:            # [P,bs,KV,Dh] <- [1,max_len,KV,Dh]
                    blocks = sl[0, :span].reshape(n_grant, bs,
                                                  *sl.shape[2:])
                    out[key] = pl.at[phys].set(blocks.astype(pl.dtype))
            if self._scan:
                out["length"] = pool_layer["length"].at[:, row].set(length)
            else:
                out["length"] = pool_layer["length"].at[row].set(length)
            return out

        layers = self.cache["layers"]
        if self._scan:
            self.cache = {"layers": put(layers, solo_cache["layers"])}
        else:
            self.cache = {"layers": [
                put(pl, sl) for pl, sl in zip(layers,
                                              solo_cache["layers"])]}
        self._len[row] = length      # joins prepare_step's advance loop
        self.sync()

    def sync(self) -> None:
        """Push the host-side master block table into every layer's
        ``table`` leaf (all layers share one table).  No-op when the
        device copy is current."""
        if not self._dirty:
            return
        t = jnp.asarray(self._table)
        layers = self.cache["layers"]
        if self._scan:
            layers["table"] = jnp.broadcast_to(
                t, layers["table"].shape)
        else:
            for ld in layers:
                ld["table"] = t
        self._dirty = False


def cache_bytes_per_slot(model, max_len: int, dtype=jnp.float32) -> int:
    """Bytes one slot (batch row) of the KV cache occupies — computed
    from ``init_cache`` shapes via eval_shape, no allocation."""
    cfg = model.cfg
    shapes = jax.eval_shape(
        lambda: api.init_cache(cfg, 1, max_len, dtype))
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(shapes))


def suggest_slots(model, plan, max_len: int, *,
                  sram_capacity_bytes: int = 64 << 20,
                  dtype=jnp.float32, max_slots: int = 64) -> int:
    """KV slots that fit beside the plan's SRAM-resident weights.

    The placement plan already commits SRAM to the ReBranch cores and to
    any full-SRAM sites (``PlanStats.branch_bits + sram_bits``); the KV
    pool lives in the remainder of the die's SRAM capacity.  Always at
    least 1 (a pool that can't hold one request isn't a pool), at most
    ``max_slots`` (scheduler batches past ~64 rows want sharding, not a
    wider pool).
    """
    per_slot = cache_bytes_per_slot(model, max_len, dtype)
    resident = 0
    if plan is not None:
        stats = plan.stats(model.cfg)
        resident = (stats.branch_bits + stats.sram_bits) // 8
    budget = max(0, sram_capacity_bytes - resident)
    return max(1, min(max_slots, budget // per_slot))


def suggest_paged(model, plan, max_len: int, *,
                  sram_capacity_bytes: int = 64 << 20,
                  dtype=jnp.float32, max_rows: int = 64,
                  block_size: int | None = None) -> tuple[int, int, int]:
    """(n_rows, n_blocks, block_size) for a :class:`PagedPool` in the
    SAME byte budget :func:`suggest_slots` would spend on dense rows.

    The block size is derived from :func:`cache_bytes_per_slot`: one
    dense slot costs ``per_slot`` bytes over ``max_len`` positions, so a
    block of ``block_size`` positions costs
    ``per_slot * block_size / max_len`` — the budget divided by that is
    the block count.  Default block size is ``max_len // 8`` clamped to
    [8, 64] and rounded to a divisor of ``max_len`` (the paged view
    must keep the dense attention geometry).  Rows are sized so the
    pool can hold ``2x`` the dense slot count of all-half-length
    requests — the fragmentation win paging exists for — capped at
    ``max_rows``.
    """
    dense = suggest_slots(model, plan, max_len,
                          sram_capacity_bytes=sram_capacity_bytes,
                          dtype=dtype, max_slots=max_rows)
    if block_size is None:
        block_size = min(64, max(8, max_len // 8))
        while max_len % block_size:
            block_size -= 1
    if max_len % block_size:
        raise ValueError(
            f"block_size {block_size} does not divide max_len {max_len}")
    blocks_per_slot = max_len // block_size
    n_blocks = max(blocks_per_slot, dense * blocks_per_slot)
    n_rows = max(1, min(max_rows, 2 * dense))
    return n_rows, n_blocks, block_size
