"""Slot-based KV-cache pool: one resident cache, rows owned by requests.

One ``CompiledModel.init_cache(n_slots, max_len)`` tree is allocated up
front; each concurrent request owns one batch row ("slot") for its
lifetime.  Admission copies a solo-prefilled (batch=1) cache into the
slot row — bitwise, no rescale — so a request's decode continues from
exactly the state the solo path would hold.  Retirement just returns
the slot: stale rows are dead weight until the next adoption overwrites
them (decode may keep writing garbage into free rows; nothing reads it
because every row's validity mask follows its own ``length``).

Pool sizing comes from the :class:`~repro.plan.PlacementPlan`'s SRAM
residency stats: the branch cores and any SRAM-resident sites already
occupy on-die SRAM, and the KV slots live in what remains of the
activation budget (:func:`suggest_slots`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import api


def _batch_axis(cfg) -> int:
    """Batch axis of every cache leaf: 1 under scan-stacked layers
    (leaves carry a leading L dim), 0 otherwise."""
    return 1 if getattr(cfg, "scan_layers", False) else 0


class SlotPool:
    """N cache rows + a free list; adoption and release are O(1)."""

    def __init__(self, model, n_slots: int, max_len: int,
                 dtype=jnp.float32):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.model = model
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.dtype = dtype
        self._axis = _batch_axis(model.cfg)
        self.cache = model.init_cache(n_slots, max_len, dtype=dtype)
        self._free = list(range(n_slots))[::-1]     # pop() -> slot 0 first

    # -- bookkeeping ----------------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> int:
        return self.n_slots - len(self._free)

    def alloc(self) -> int | None:
        return self._free.pop() if self._free else None

    def release(self, slot: int) -> None:
        if not (0 <= slot < self.n_slots):
            raise ValueError(f"slot {slot} outside pool of {self.n_slots}")
        if slot in self._free:
            raise ValueError(f"slot {slot} double-released")
        self._free.append(slot)

    # -- cache row transfer ---------------------------------------------
    def adopt(self, slot: int, solo_cache) -> None:
        """Copy a batch=1 cache into ``slot``'s row, leaf by leaf."""
        axis = self._axis

        def put(pool_leaf, solo_leaf):
            row = jax.lax.index_in_dim(solo_leaf, 0, axis, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(
                pool_leaf, row.astype(pool_leaf.dtype), slot, axis)

        self.cache = jax.tree.map(put, self.cache, solo_cache)

    def solo_cache(self):
        """A fresh batch=1 cache with this pool's geometry (for the
        admission prefill; same max_len so adopted rows line up)."""
        return self.model.init_cache(1, self.max_len, dtype=self.dtype)


def cache_bytes_per_slot(model, max_len: int, dtype=jnp.float32) -> int:
    """Bytes one slot (batch row) of the KV cache occupies — computed
    from ``init_cache`` shapes via eval_shape, no allocation."""
    cfg = model.cfg
    shapes = jax.eval_shape(
        lambda: api.init_cache(cfg, 1, max_len, dtype))
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(shapes))


def suggest_slots(model, plan, max_len: int, *,
                  sram_capacity_bytes: int = 64 << 20,
                  dtype=jnp.float32, max_slots: int = 64) -> int:
    """KV slots that fit beside the plan's SRAM-resident weights.

    The placement plan already commits SRAM to the ReBranch cores and to
    any full-SRAM sites (``PlanStats.branch_bits + sram_bits``); the KV
    pool lives in the remainder of the die's SRAM capacity.  Always at
    least 1 (a pool that can't hold one request isn't a pool), at most
    ``max_slots`` (scheduler batches past ~64 rows want sharding, not a
    wider pool).
    """
    per_slot = cache_bytes_per_slot(model, max_len, dtype)
    resident = 0
    if plan is not None:
        stats = plan.stats(model.cfg)
        resident = (stats.branch_bits + stats.sram_bits) // 8
    budget = max(0, sram_capacity_bytes - resident)
    return max(1, min(max_slots, budget // per_slot))
