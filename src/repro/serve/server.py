"""The front door: ``serve.load(model_id)`` -> a server with submit().

One entry point covers both serve surfaces:

  * LM configs get :class:`LMServer` — the continuous batcher behind a
    synchronous ``submit``/``drain`` pair plus an async ``generate``
    coroutine (concurrent callers share the batch; the decode loop is
    pumped cooperatively, one tick per waiter round).
  * CNN configs get :class:`CNNServer` — forward-only micro-batching:
    submitted images ride one fixed-geometry jit'd forward in pool-sized
    chunks (one compile, any request count).

Both are views over the SAME resident cell per model id (the registry
compiles at most once per process): serving more users never re-stages
the ROM trunk.
"""

from __future__ import annotations

import asyncio

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, cnn
from repro.serve import registry
from repro.serve.pool import (PagedPool, SlotPool, suggest_paged,
                              suggest_slots)
from repro.serve.scheduler import ContinuousBatcher


class LMServer:
    """Continuous-batching decode serving for one resident LM cell.

    The KV pool is PAGED by default for families that support it
    (``paged=None`` -> ``api.supports_paging``): requests share one
    block pool through per-request block tables instead of each pinning
    a full-horizon cache row, so mixed-length traffic packs more
    concurrent requests into the same plan-budgeted bytes.  Pass
    ``paged=False`` for the dense :class:`~repro.serve.pool.SlotPool`,
    or ``paged=True`` to demand paging (raises for families that cannot
    page, e.g. SWA rings / ssm state).  ``n_blocks``/``block_size``
    size the paged pool (defaults: dense-equivalent capacity in
    ``max_len // 8``-position blocks); ``prefill_chunk`` is forwarded
    to the batcher (chunked prefill admission).

    With a :class:`~repro.scenario.ScenarioStore` attached, one cell
    serves N scenarios: ``swap_scenario`` (or ``submit(...,
    scenario=...)``) queues a branch hot-swap behind the in-flight
    requests — zero trunk recompile, zero ROM traffic, and every
    request decodes entirely under the scenario it was admitted with.

    ``spec_k > 0`` turns on speculative decode (the YOLoC-native
    draft/verify split — see ``serve.scheduler``): up to ``spec_k``
    tokens per row drafted by the branch-only model (ROM trunks
    skipped), then one batched full-cell ``verify_step`` per round.
    Output stays bit-identical to ``spec_k=0`` greedy decode.
    ``draft_source`` optionally replaces the branch drafter with a
    callable (benchmarks use it to dial acceptance rates).
    """

    def __init__(self, model, params, *, n_slots: int, max_len: int,
                 dtype=jnp.float32, store=None, scenario=None,
                 paged: bool | None = None, n_blocks: int | None = None,
                 block_size: int | None = None,
                 prefill_chunk: int | None = None, spec_k: int = 0,
                 draft_source=None):
        self.model = model
        self.store = store
        if paged is None:
            paged = api.supports_paging(model.cfg)
        elif paged and not api.supports_paging(model.cfg):
            raise ValueError(
                f"paged=True but {model.cfg.name!r} (family "
                f"{model.cfg.family!r}, sliding_window="
                f"{model.cfg.sliding_window}) cannot page its KV cache; "
                f"pass paged=False for a dense SlotPool")
        if paged:
            if block_size is None:
                block_size = min(64, max(8, max_len // 8))
                while max_len % block_size:
                    block_size -= 1
            if n_blocks is None:
                # dense-equivalent byte budget: n_slots full horizons
                n_blocks = n_slots * (max_len // block_size)
            self.pool = PagedPool(model, n_slots, n_blocks, block_size,
                                  max_len, dtype=dtype)
        else:
            self.pool = SlotPool(model, n_slots, max_len, dtype=dtype)
        self.batcher = ContinuousBatcher(model, params, self.pool,
                                         scenario=scenario,
                                         prefill_chunk=prefill_chunk,
                                         spec_k=spec_k,
                                         draft_source=draft_source)

    @property
    def params(self):
        """The live params tree (the batcher owns it: scenario swaps
        donate the old tree, so this is the ONE valid reference)."""
        return self.batcher.params

    @property
    def scenario(self):
        return self.batcher.scenario

    def swap_scenario(self, name: str):
        """Queue a hot-swap to a registered scenario's branch (applies
        at a decode-step boundary after in-flight requests retire)."""
        if self.store is None:
            raise ValueError(
                "no ScenarioStore attached to this server; serve.load"
                "(model_id, scenario=...) or pass store= to LMServer")
        self.batcher.swap(name, self.store.get(name))

    # -- sync surface ---------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, eos_id=None,
               scenario=None):
        if scenario is not None and \
                scenario != self.batcher.pending_scenario():
            self.swap_scenario(scenario)
        return self.batcher.submit(prompt, max_new_tokens, eos_id=eos_id,
                                   scenario=scenario)

    def step(self) -> bool:
        return self.batcher.step()

    def drain(self, max_steps: int | None = None) -> int:
        return self.batcher.drain(max_steps)

    # -- async surface --------------------------------------------------
    async def generate(self, prompt, max_new_tokens: int,
                       eos_id=None, scenario=None) -> list[int]:
        """Submit and await one request; concurrent callers batch.

        Cooperative pump: each waiter advances the shared scheduler one
        tick per event-loop round, so N concurrent ``generate`` calls
        decode as one batch instead of N solo loops.
        """
        req = self.submit(prompt, max_new_tokens, eos_id=eos_id,
                          scenario=scenario)
        while not req.done:
            self.batcher.step()
            await asyncio.sleep(0)
        return list(req.tokens)


class CNNServer:
    """Forward-only serving for CNN configs: one jit'd fixed-batch cell.

    Requests are padded into ``n_slots``-row chunks so every call hits
    the same compiled executable; pad rows are sliced off the output
    (inference BN uses frozen statistics, so rows are independent and
    padding never changes a real row's result).
    """

    def __init__(self, model, params, *, n_slots: int, store=None,
                 scenario=None):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.model = model
        self.params = params
        self.store = store
        self.scenario = scenario
        self.n_slots = int(n_slots)
        self._forward = jax.jit(model.forward)

    def swap_scenario(self, name: str):
        """Hot-swap to a registered scenario's branch.  Forward serving
        is synchronous, so the swap applies immediately (there are no
        in-flight requests to protect); the jitted forward is reused —
        no recompile, no trunk traffic."""
        if self.store is None:
            raise ValueError(
                "no ScenarioStore attached to this server; serve.load"
                "(model_id, scenario=...) or pass store= to CNNServer")
        from repro.scenario import swap_params
        self.params = swap_params(self.params, self.store.get(name))
        self.scenario = name

    def submit(self, images) -> np.ndarray:
        """images: [B, H, W, C] -> model outputs for all B rows."""
        images = jnp.asarray(images)
        if images.ndim == 3:
            images = images[None]
        outs = []
        for lo in range(0, images.shape[0], self.n_slots):
            chunk = images[lo:lo + self.n_slots]
            pad = self.n_slots - chunk.shape[0]
            if pad:
                chunk = jnp.concatenate(
                    [chunk, jnp.zeros((pad, *chunk.shape[1:]),
                                      chunk.dtype)], 0)
            out = self._forward(self.params, chunk)
            outs.append(np.asarray(out[:self.n_slots - pad]
                                   if pad else out))
        return np.concatenate(outs, 0)

    async def generate(self, image) -> np.ndarray:
        """Async single-image front door (symmetry with LMServer)."""
        await asyncio.sleep(0)
        return self.submit(image[None] if np.asarray(image).ndim == 3
                           else image)[0]


def load(model_id: str, *, params=None, key=None, n_slots=None,
         max_len: int = 128, dtype=jnp.float32,
         sram_capacity_bytes: int = 64 << 20, scenario: str | None = None,
         paged: bool | None = None, n_blocks: int | None = None,
         block_size: int | None = None, prefill_chunk: int | None = None,
         spec_k: int = 0, draft_source=None):
    """One front door for LM decode and CNN forward serving.

    Resolves ``model_id`` through the registry (the cell is compiled at
    most once per process), initialises params unless given, and sizes
    the KV pool from the entry's placement plan when ``n_slots`` is not
    forced: dense pools via :func:`~repro.serve.pool.suggest_slots`,
    paged pools via :func:`~repro.serve.pool.suggest_paged` (same byte
    budget, roughly 2x the rows — short requests only pin the blocks
    they fill).  ``paged``/``n_blocks``/``block_size``/``prefill_chunk``
    /``spec_k``/``draft_source`` are forwarded to :class:`LMServer`
    (ignored for CNN configs, which have no KV state and do not decode).

    scenario: start the server on a registered scenario's branch (see
    ``registry.scenario_store`` / ``repro.scenario``): the branch is
    implanted over the resident trunk before serving, and the returned
    server carries the store so ``swap_scenario`` / ``submit(...,
    scenario=...)`` can hot-swap to the other registered scenarios.
    """
    model, plan = registry.compile_entry(model_id)
    if params is None:
        params = model.init(key if key is not None
                            else jax.random.PRNGKey(0))
    store = registry.scenario_store(model_id) \
        if scenario is not None or registry.has_scenarios(model_id) \
        else None
    if scenario is not None:
        from repro.scenario import swap_params
        params = swap_params(params, store.get(scenario))
    if isinstance(model.cfg, cnn.CNNConfig):
        return CNNServer(model, params, n_slots=n_slots or 8,
                         store=store, scenario=scenario)
    if paged is None:
        paged = api.supports_paging(model.cfg)
    if n_slots is None:
        if paged:
            n_slots, nb, bs = suggest_paged(
                model, plan, max_len, dtype=dtype,
                sram_capacity_bytes=sram_capacity_bytes,
                block_size=block_size)
            n_blocks = n_blocks if n_blocks is not None else nb
            block_size = bs
        else:
            n_slots = suggest_slots(
                model, plan, max_len, dtype=dtype,
                sram_capacity_bytes=sram_capacity_bytes)
    return LMServer(model, params, n_slots=n_slots, max_len=max_len,
                    dtype=dtype, store=store, scenario=scenario,
                    paged=paged, n_blocks=n_blocks, block_size=block_size,
                    prefill_chunk=prefill_chunk, spec_k=spec_k,
                    draft_source=draft_source)
