"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, peak_lr: float, warmup_steps: int,
                       total_steps: int, min_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / max(1, warmup_steps)
    t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                 0.0, 1.0)
    cos = peak_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, lr: float):
    del step
    return lr
