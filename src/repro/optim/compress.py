"""Error-feedback int8 gradient compression for the branch all-reduce.

At 1000+ node scale the gradient all-reduce is the dominant train-time
collective.  ReBranch already shrinks it 16x (only branch cores have
grads); this module shrinks the remaining volume a further ~4x by
all-gathering int8-quantised shards with per-row scales and summing the
dequantised copies locally, with persistent error feedback so the
quantisation noise is unbiased over time (Seide et al. / EF-SGD).

Used inside shard_map over the data axis (see launch/train.py --compress).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_with_feedback(g, err):
    """(g + err) -> int8 + scale; returns (q, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    flat = target.reshape(-1)
    absmax = jnp.max(jnp.abs(flat))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = target - deq.reshape(target.shape)
    return q.reshape(target.shape), scale, new_err


def all_reduce_int8(g, err, axis_name: str):
    """Compressed mean-all-reduce of one gradient tensor over ``axis_name``.

    Wire volume: int8 payload + one f32 scale per device (vs f32/bf16 for a
    plain psum) — a 4x/2x reduction.  Error feedback keeps the long-run
    bias at zero.
    """
    q, scale, new_err = quantize_with_feedback(g, err)
    qs = jax.lax.all_gather(q, axis_name)                # [D, ...] int8 wire
    ss = jax.lax.all_gather(scale, axis_name)            # [D] f32
    n = qs.shape[0]
    summed = jnp.tensordot(ss, qs.astype(jnp.float32).reshape(n, -1),
                           axes=1).reshape(g.shape)
    return (summed / n).astype(g.dtype), new_err


def tree_all_reduce_int8(grads, err_state, axis_name: str):
    """Apply compressed all-reduce leaf-wise; err_state mirrors grads."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        rg, re = all_reduce_int8(g, e, axis_name)
        out_g.append(rg)
        out_e.append(re)
    return (jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_e))


def init_error_state(trainable):
    return jax.tree.map(
        lambda p: None if p is None else jnp.zeros(p.shape, jnp.float32),
        trainable, is_leaf=lambda x: x is None)
