from repro.optim.adamw import AdamWConfig, init, update, global_norm
from repro.optim import schedule, compress

__all__ = ["AdamWConfig", "init", "update", "global_norm", "schedule",
           "compress"]
