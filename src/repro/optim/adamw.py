"""AdamW over the *trainable* (SRAM) pytree only.

The ROM trunk never enters optimizer state — with D*U=16 branch
compression this shrinks optimizer memory by ~16x vs full fine-tuning
(the training-side payoff of the paper's ROM/SRAM split).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3               # may be overridden per-step by schedule
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def init(trainable) -> dict:
    zeros = lambda: jax.tree.map(
        lambda p: None if p is None else jnp.zeros_like(p, jnp.float32),
        trainable, is_leaf=lambda x: x is None)
    return {"step": jnp.zeros((), jnp.int32), "m": zeros(), "v": zeros()}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree) if x is not None]
    return jnp.sqrt(sum(leaves) + 1e-30)


def update(grads, state, params, cfg: AdamWConfig,
           lr: jax.Array | float | None = None):
    """Returns (new_params, new_state, metrics)."""
    lr = cfg.lr if lr is None else lr
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        if g is None or p is None:
            return None, None, None
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m, v

    isnone = lambda x: x is None
    out = jax.tree.map(upd, grads, state["m"], state["v"], params,
                       is_leaf=isnone)
    # unzip the 3-tuples
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"grad_norm": gnorm}
