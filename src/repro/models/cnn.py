"""The paper's own models: VGG-8, ResNet-18, DarkNet-19, Tiny-YOLO.

ReBranchConv (paper Fig. 7-8): frozen int8 trunk conv (ROM) in parallel
with  1x1 compress -> KxK trainable core conv -> 1x1 decompress  (branch;
the point-wise (de)compression layers are fixed, only the core trains).
With D=U=4 the branch holds 1/16 of the trunk parameters.

NHWC layout.  The trunk conv resolves ``spec.trunk_impl`` through the
``repro.engine`` registry (the same TrunkEngine the ReBranch linears use
— 'int8_native' / 'dequant' / 'pallas' out of the box, strict resolution,
every backward the straight-through estimator so branch training is
identical under all engines).  Per-layer engine / ROM-vs-SRAM overrides
come in through ``cfg.rebranch_overrides`` (see ``config.spec_for`` and
``repro.deploy.compile_model``); each conv is addressed by a site name
('convs.3', 'stem', 'stages.1.0.conv2', 'head.0', ...).

With ``cfg.fuse_bn_act`` the inference BN affine + activation fold into
the trunk conv's engine epilogue (one fused pass instead of three
feature-map sweeps) — numerically the same inference-style BN.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engine_lib
from repro.core import rebranch as rebranch_lib
from repro.core.rebranch import ReBranchSpec
from repro.distributed.sharding import shard
from repro.engine import base as engine_base
from repro.models.config import spec_for


# ---------------------------------------------------------------------------
# ReBranch convolution
# ---------------------------------------------------------------------------

_conv = rebranch_lib.conv_nhwc


def _pool(x):
    """2x2 max pool + re-constrain onto the CNN serving layout (batch over
    pod, spatial H over data — the halo-exchange conv's native sharding).
    The constraint keeps GSPMD from drifting to a replicated layout after
    the windowed reduction; no-op without a mesh."""
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                              (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    return shard(x, "cnn_batch", "cnn_h")


def init_conv(key, k: int, c_in: int, c_out: int, spec: ReBranchSpec,
              *, w_init=None):
    ks = jax.random.split(key, 3)
    if w_init is None:
        w_init = (jax.random.normal(ks[0], (k, k, c_in, c_out), jnp.float32)
                  * np.sqrt(2.0 / (k * k * c_in)))
    if not spec.enabled:
        return {"sram": {"w": w_init}}
    absmax = jnp.max(jnp.abs(w_init), axis=(0, 1, 2), keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    w_q = jnp.clip(jnp.round(w_init / scale), -127, 127).astype(jnp.int8)
    p = {"rom": {"w_q": w_q, "w_scale": scale}, "sram": {}}
    if spec.branch_enabled:
        c_c = max(1, c_in // spec.d_ratio)
        c_u = max(1, c_out // spec.u_ratio)
        p["rom"]["C"] = (jax.random.normal(ks[1], (1, 1, c_in, c_c))
                         / np.sqrt(c_in)).astype(jnp.float32)
        p["rom"]["U"] = (jax.random.normal(ks[2], (1, 1, c_u, c_out))
                         / np.sqrt(c_u)).astype(jnp.float32)
        p["sram"]["core"] = jnp.zeros((k, k, c_c, c_u), jnp.float32)
    return p


def apply_conv(params, x, spec: ReBranchSpec, stride: int = 1,
               epilogue: engine_base.ConvEpilogue | None = None):
    """One ReBranch conv through the resolved TrunkEngine.

    epilogue: optional per-channel affine + activation folded into the
    trunk pass (the scale rides the engine's existing dequant epilogue;
    with a live branch the activation is deferred until after the branch
    add so act(BN(trunk + branch)) semantics are preserved).
    """
    if not spec.enabled:
        return engine_base.finish(_conv(x, params["sram"]["w"], stride),
                                  epilogue)
    rom = params["rom"]
    eng = engine_lib.resolve(spec)          # strict + capability-gated
    has_branch = spec.branch_enabled and "core" in params["sram"]
    # engines without epilogue support get None (handing them one would be
    # silently dropped); the layer applies the whole epilogue itself then
    fuse = epilogue is not None and eng.capabilities.epilogue
    if has_branch and "conv" in eng.capabilities.fused_ops:
        # one pass over the shared patch matrix computes trunk AND branch;
        # the epilogue applies after the in-kernel branch add, exactly the
        # act(BN(trunk + branch)) the unfused path reconstructs below
        y = eng.fused_conv(spec.cim, x, rom["w_q"], rom["w_scale"],
                           rom["C"], params["sram"]["core"], rom["U"],
                           stride=stride, padding="SAME",
                           epilogue=epilogue if fuse else None)
        return y if fuse else engine_base.finish(y, epilogue)
    trunk_ep = (epilogue.without_act() if has_branch else epilogue) \
        if fuse else None
    y = eng.conv(spec.cim, x, rom["w_q"], rom["w_scale"],
                 stride=stride, padding="SAME", epilogue=trunk_ep)
    if has_branch:
        t = _conv(x, rom["C"].astype(x.dtype), 1)
        t = _conv(t, params["sram"]["core"].astype(x.dtype), stride)
        b = _conv(t, rom["U"].astype(x.dtype), 1)
        if fuse:
            if epilogue.scale is not None:
                b = b * epilogue.scale.astype(b.dtype)
            return engine_base.activate(y + b, epilogue)
        return engine_base.finish(y + b, epilogue)
    return y if fuse or epilogue is None else engine_base.finish(y, epilogue)


def conv_trainable_frac(spec: ReBranchSpec) -> float:
    return 1.0 / (spec.d_ratio * spec.u_ratio)


def freeze_to_rom(params, key, spec: ReBranchSpec):
    """'Tape-out' a pretrained all-trainable CNN: every plain conv
    ({'sram': {'w': [k,k,cin,cout]}}) becomes a ReBranch conv (int8 ROM
    trunk + fixed C/U + zero-init trainable core).  Dense heads (2D 'w')
    and BN stay trainable ("SRAM")."""
    idx = [0]

    def conv_node(node):
        w = node["sram"]["w"]
        if w.ndim != 4:
            return node                      # dense head: stays SRAM
        idx[0] += 1
        sub = jax.random.fold_in(key, idx[0])
        return init_conv(sub, w.shape[0], w.shape[2], w.shape[3], spec,
                         w_init=w)

    def walk(node):
        if isinstance(node, dict):
            if set(node.keys()) == {"sram"} and "w" in node["sram"]:
                return conv_node(node)
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def _bn_init(c):
    return {"sram": {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,)),
                     "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}}


def bn_epilogue(bn_params, act: str | None = None) -> engine_base.ConvEpilogue:
    """Inference BN (frozen statistics; YOLoC deploys inference chips), plus
    an optional activation, as a fusable conv epilogue: a per-output-channel
    affine that rides the trunk's dequant multiply in one fused elementwise
    pass.  The ONE home of the BN affine — _bn_apply is defined from it."""
    s = bn_params["sram"]
    inv = jax.lax.rsqrt(s["var"] + 1e-5) * s["scale"]
    return engine_base.ConvEpilogue(scale=inv, bias=s["bias"] - s["mean"] * inv,
                                    act=act)


def _bn_apply(p, x, train: bool = False):
    return engine_base.finish(x, bn_epilogue(p))


def _leaky(x):
    return jax.nn.leaky_relu(x, 0.1)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    num_classes: int = 100
    input_size: int = 32
    rebranch: ReBranchSpec = dataclasses.field(default_factory=ReBranchSpec)
    head_anchors: int = 5            # YOLO heads
    head_classes: int = 20           # VOC
    # per-layer mapping overrides ((site, ReBranchSpec), ...) — see
    # config.spec_for / repro.deploy.compile_model
    rebranch_overrides: tuple = ()
    # fold BN + activation into the trunk conv's engine epilogue
    fuse_bn_act: bool = False


# ---------------------------------------------------------------------------
# VGG-8  (paper's CIFAR classifier)
# ---------------------------------------------------------------------------

VGG8_CHANNELS = (64, 64, 128, 128, 256, 256)   # conv layers, pool every 2


def init_vgg8(key, cfg: CNNConfig):
    keys = jax.random.split(key, len(VGG8_CHANNELS) + 1)
    convs, bns = [], []
    c_in = 3
    for i, c in enumerate(VGG8_CHANNELS):
        convs.append(init_conv(keys[i], 3, c_in, c,
                               spec_for(cfg, f"convs.{i}")))
        bns.append(_bn_init(c))
        c_in = c
    fc = {"sram": {
        "w": jax.random.normal(keys[-1],
                               (c_in * (cfg.input_size // 8) ** 2,
                                cfg.num_classes)) * 0.01,
        "b": jnp.zeros((cfg.num_classes,))}}
    return {"convs": convs, "bns": bns, "fc": fc}


def apply_vgg8(params, x, cfg: CNNConfig):
    for i, (conv, bn) in enumerate(zip(params["convs"], params["bns"])):
        spec = spec_for(cfg, f"convs.{i}")
        if cfg.fuse_bn_act:
            x = apply_conv(conv, x, spec, epilogue=bn_epilogue(bn, "relu"))
        else:
            x = jax.nn.relu(_bn_apply(bn, apply_conv(conv, x, spec)))
        if i % 2 == 1:
            x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc"]["sram"]["w"] + params["fc"]["sram"]["b"]


# ---------------------------------------------------------------------------
# ResNet-18 (CIFAR variant)
# ---------------------------------------------------------------------------

RESNET18_STAGES = ((64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2))


def init_resnet18(key, cfg: CNNConfig):
    key, k0 = jax.random.split(key)
    params = {"stem": init_conv(k0, 3, 3, 64, spec_for(cfg, "stem")),
              "stem_bn": _bn_init(64), "stages": []}
    c_in = 64
    for si, (c_out, blocks, stride) in enumerate(RESNET18_STAGES):
        stage = []
        for b in range(blocks):
            key, k1, k2, k3 = jax.random.split(key, 4)
            st = stride if b == 0 else 1
            site = f"stages.{si}.{b}"
            blk = {
                "conv1": init_conv(k1, 3, c_in, c_out,
                                   spec_for(cfg, f"{site}.conv1")),
                "bn1": _bn_init(c_out),
                "conv2": init_conv(k2, 3, c_out, c_out,
                                   spec_for(cfg, f"{site}.conv2")),
                "bn2": _bn_init(c_out),
            }
            if st != 1 or c_in != c_out:
                blk["proj"] = init_conv(k3, 1, c_in, c_out,
                                        spec_for(cfg, f"{site}.proj"))
                blk["proj_bn"] = _bn_init(c_out)
            stage.append(blk)
            c_in = c_out
        params["stages"].append(stage)
    key, kf = jax.random.split(key)
    params["fc"] = {"sram": {
        "w": jax.random.normal(kf, (512, cfg.num_classes)) * 0.01,
        "b": jnp.zeros((cfg.num_classes,))}}
    return params


def apply_resnet18(params, x, cfg: CNNConfig):
    def conv_bn(conv_p, bn_p, xx, spec, st=1, act=None):
        # fuse_bn_act: the BN affine always folds into the conv epilogue;
        # the activation only where it legally follows the conv (bn2 /
        # proj_bn feed the residual add, so their act stays outside)
        if cfg.fuse_bn_act:
            return apply_conv(conv_p, xx, spec, st,
                              epilogue=bn_epilogue(bn_p, act))
        y = _bn_apply(bn_p, apply_conv(conv_p, xx, spec, st))
        return jax.nn.relu(y) if act == "relu" else y

    x = conv_bn(params["stem"], params["stem_bn"], x,
                spec_for(cfg, "stem"), act="relu")
    for si, (stage, (_, _, stride)) in enumerate(
            zip(params["stages"], RESNET18_STAGES)):
        for b, blk in enumerate(stage):
            st = stride if b == 0 else 1
            site = f"stages.{si}.{b}"
            h = conv_bn(blk["conv1"], blk["bn1"], x,
                        spec_for(cfg, f"{site}.conv1"), st, act="relu")
            h = conv_bn(blk["conv2"], blk["bn2"], h,
                        spec_for(cfg, f"{site}.conv2"))
            sc = x
            if "proj" in blk:
                sc = conv_bn(blk["proj"], blk["proj_bn"], x,
                             spec_for(cfg, f"{site}.proj"), st)
            x = shard(jax.nn.relu(h + sc), "cnn_batch", "cnn_h")
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["fc"]["sram"]["w"] + params["fc"]["sram"]["b"]


# ---------------------------------------------------------------------------
# DarkNet-19 backbone + YOLO head (the paper's headline model), Tiny-YOLO
# ---------------------------------------------------------------------------

# (channels, kernel) per layer; 'M' = maxpool  — DarkNet-19 (YOLOv2 backbone)
DARKNET19 = [
    (32, 3), "M", (64, 3), "M",
    (128, 3), (64, 1), (128, 3), "M",
    (256, 3), (128, 1), (256, 3), "M",
    (512, 3), (256, 1), (512, 3), (256, 1), (512, 3), "M",
    (1024, 3), (512, 1), (1024, 3), (512, 1), (1024, 3),
]

TINY_YOLO = [
    (16, 3), "M", (32, 3), "M", (64, 3), "M", (128, 3), "M",
    (256, 3), "M", (512, 3), "M", (1024, 3),
]


def _init_darknet(key, plan, cfg: CNNConfig, head_convs):
    convs, bns = [], []
    c_in = 3
    ci = 0
    for item in plan:
        if item == "M":
            continue                      # pools carry no params
        c, k = item
        key, k1 = jax.random.split(key)
        convs.append(init_conv(k1, k, c_in, c, spec_for(cfg, f"convs.{ci}")))
        bns.append(_bn_init(c))
        c_in = c
        ci += 1
    # detection head: conv stack + 1x1 predictor (trainable — "SRAM")
    head = []
    for hi, (c, k) in enumerate(head_convs):
        key, k1 = jax.random.split(key)
        head.append({"conv": init_conv(k1, k, c_in, c,
                                       spec_for(cfg, f"head.{hi}")),
                     "bn": _bn_init(c)})
        c_in = c
    key, k1 = jax.random.split(key)
    n_out = cfg.head_anchors * (5 + cfg.head_classes)
    # the 1x1 predictor is always a plain trainable conv (no site: there
    # is nothing to override — it never freezes into ROM)
    pred = init_conv(k1, 1, c_in, n_out,
                     dataclasses.replace(cfg.rebranch, enabled=False))
    return {"convs": convs, "bns": bns, "head": head, "pred": pred}


def init_darknet19(key, cfg: CNNConfig):
    return _init_darknet(key, DARKNET19, cfg,
                         head_convs=[(1024, 3), (1024, 3)])


def init_tiny_yolo(key, cfg: CNNConfig):
    return _init_darknet(key, TINY_YOLO, cfg, head_convs=[(512, 3)])


def apply_darknet(params, x, cfg: CNNConfig):
    plan = DARKNET19 if cfg.name == "darknet19" else TINY_YOLO

    def conv_bn_leaky(conv_p, bn_p, xx, spec):
        if cfg.fuse_bn_act:
            return apply_conv(conv_p, xx, spec,
                              epilogue=bn_epilogue(bn_p, "leaky_relu"))
        return _leaky(_bn_apply(bn_p, apply_conv(conv_p, xx, spec)))

    i = 0
    for item in plan:
        if item == "M":
            x = _pool(x)
        else:
            x = conv_bn_leaky(params["convs"][i], params["bns"][i], x,
                              spec_for(cfg, f"convs.{i}"))
            i += 1
    for hi, blk in enumerate(params["head"]):
        x = conv_bn_leaky(blk["conv"], blk["bn"], x,
                          spec_for(cfg, f"head.{hi}"))
    x = apply_conv(params["pred"], x,
                   dataclasses.replace(cfg.rebranch, enabled=False))
    b, h, w, _ = x.shape
    return x.reshape(b, h, w, cfg.head_anchors, 5 + cfg.head_classes)


MODEL_REGISTRY = {
    "vgg8": (init_vgg8, apply_vgg8),
    "resnet18": (init_resnet18, apply_resnet18),
    "darknet19": (init_darknet19, apply_darknet),
    "tiny_yolo": (init_tiny_yolo, apply_darknet),
}


def conv_site_shapes(cfg: CNNConfig) -> list | None:
    """Every conv site this config's init/apply consult through spec_for,
    with its geometry: ``(site, k, c_in, c_out, out_hw, stride)`` tuples
    in forward order (out_hw is the conv's own output resolution, the MAC
    basis: macs = out_hw^2 * k^2 * c_in * c_out per inference).

    Kept NEXT TO the model builders so a structural edit (new conv, new
    projection rule) updates the enumeration in the same file.  None for
    names outside MODEL_REGISTRY.  (The 1x1 'pred' conv has no site: it
    never freezes into ROM.)  ``repro.plan.sites`` wraps these into the
    validated site tree the placement subsystem consumes."""
    if cfg.name == "vgg8":
        out, c_in, hw = [], 3, cfg.input_size
        for i, c in enumerate(VGG8_CHANNELS):
            out.append((f"convs.{i}", 3, c_in, c, hw, 1))
            c_in = c
            if i % 2 == 1:
                hw //= 2
        return out
    if cfg.name == "resnet18":
        hw = cfg.input_size
        out, c_in = [("stem", 3, 3, 64, hw, 1)], 64
        for si, (c_out, blocks, stride) in enumerate(RESNET18_STAGES):
            for b in range(blocks):
                st = stride if b == 0 else 1
                hw_out = -(-hw // st)               # SAME stride st
                site = f"stages.{si}.{b}"
                out.append((f"{site}.conv1", 3, c_in, c_out, hw_out, st))
                out.append((f"{site}.conv2", 3, c_out, c_out, hw_out, 1))
                if st != 1 or c_in != c_out:        # same rule as init
                    out.append((f"{site}.proj", 1, c_in, c_out, hw_out, st))
                c_in, hw = c_out, hw_out
        return out
    if cfg.name in ("darknet19", "tiny_yolo"):
        plan = DARKNET19 if cfg.name == "darknet19" else TINY_YOLO
        head = ([(1024, 3), (1024, 3)] if cfg.name == "darknet19"
                else [(512, 3)])
        out, c_in, hw, ci = [], 3, cfg.input_size, 0
        for item in plan:
            if item == "M":
                hw //= 2
                continue
            c, k = item
            out.append((f"convs.{ci}", k, c_in, c, hw, 1))
            c_in = c
            ci += 1
        for hi, (c, k) in enumerate(head):
            out.append((f"head.{hi}", k, c_in, c, hw, 1))
            c_in = c
        return out
    return None


def override_sites(cfg: CNNConfig) -> set | None:
    """The site-name set of :func:`conv_site_shapes` (None when unknown)."""
    shapes = conv_site_shapes(cfg)
    return None if shapes is None else {s[0] for s in shapes}


def count_macs_and_params(init_fn, apply_fn, cfg: CNNConfig):
    """Static MAC/param counts for the energy model (jaxpr-free estimate)."""
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: init_fn(k, cfg), key)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params)
                   if hasattr(l, "shape"))
    x = jax.ShapeDtypeStruct((1, cfg.input_size, cfg.input_size, 3),
                             jnp.float32)

    macs = {"n": 0}

    def count(p, xx):
        return apply_fn(p, xx, cfg)

    # count conv MACs from the jaxpr
    jaxpr = jax.make_jaxpr(count)(params, x)

    def walk(jpr):
        for eqn in jpr.eqns:
            if eqn.primitive.name == "conv_general_dilated":
                out = eqn.outvars[0].aval.shape
                wshape = eqn.invars[1].aval.shape
                macs["n"] += int(np.prod(out)) * int(
                    np.prod(wshape[:3]))      # H*W*... * (kh*kw*cin)
            elif eqn.primitive.name in ("dot_general",):
                a = eqn.invars[0].aval.shape
                o = eqn.outvars[0].aval.shape
                macs["n"] += int(np.prod(o)) * int(a[-1])
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)
    walk(jaxpr.jaxpr)
    return n_params, macs["n"]
