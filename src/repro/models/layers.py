"""Shared model components (ReBranch-aware, sharding-annotated).

Every large linear map goes through core.rebranch (frozen int8 ROM trunk +
trainable branch); norms, biases and routers are small and stay trainable
("SRAM").  Embedding tables are ROM (int8 + scale) — lookups dequantise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant, rebranch
from repro.distributed.sharding import shard
from repro.models.config import ArchConfig


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"sram": {"scale": jnp.ones((d,), jnp.float32)}}


def apply_rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["sram"]["scale"]).astype(dtype)


# ---------------------------------------------------------------------------
# embeddings (ROM: int8 table + scale)
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, cfg: ArchConfig):
    table = jax.random.normal(key, (vocab, d), jnp.float32)
    t_q, t_scale = quant.quantize_weights(table, axis=1)   # per-token scale
    return {"rom": {"table_q": t_q, "table_scale": t_scale}}


def apply_embedding(params, ids, cfg: ArchConfig):
    t_q = params["rom"]["table_q"]
    t_s = params["rom"]["table_scale"]
    emb = t_q[ids].astype(_dt(cfg)) * t_s[ids].astype(_dt(cfg))
    return emb


def embedding_as_logits(params, x, cfg: ArchConfig):
    """Tied-embedding readout: x @ dequant(table)^T."""
    t_q = params["rom"]["table_q"]
    t_s = params["rom"]["table_scale"]
    w = t_q.astype(x.dtype) * t_s.astype(x.dtype)          # [V, d]
    return jnp.einsum("...d,vd->...v", x, w)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0, mrope: bool = False):
    """x: [B, S, H, Dh]; positions: [B, S] (or [B, S, 3] for M-RoPE).

    M-RoPE (qwen2-vl): the rotary dimensions are split into 3 sections
    (temporal / height / width) fed by 3 position streams.  For text-only
    streams all three positions coincide and M-RoPE == RoPE.
    """
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(dh, theta), jnp.float32)  # [dh/2]
    if mrope:
        if positions.ndim == 2:                      # text-only degenerate
            positions = jnp.broadcast_to(positions[..., None],
                                         (*positions.shape, 3))
        n = dh // 2
        # section split 2:1:1 over rotary dims (t, h, w)
        sec = np.array([n - 2 * (n // 4), n // 4, n // 4])
        sel = np.repeat(np.arange(3), sec)           # [dh/2] -> section id
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(jnp.asarray(sel)[None, None, :],
                             (*positions.shape[:2], n)).astype(jnp.int32),
            axis=-1)                                  # [B, S, dh/2]
        angles = pos * freqs[None, None, :]
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA + KV cache + chunked causal / sliding window)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    spec = cfg.rebranch
    h, kv, dh, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "q": rebranch.init_linear(ks[0], d, h * dh, spec, use_bias=cfg.qkv_bias),
        "k": rebranch.init_linear(ks[1], d, kv * dh, spec, use_bias=cfg.qkv_bias),
        "v": rebranch.init_linear(ks[2], d, kv * dh, spec, use_bias=cfg.qkv_bias),
        "o": rebranch.init_linear(ks[3], h * dh, d, spec),
    }


def _chunked_causal_attention(q, k, v, chunk: int, window: int = 0,
                              kv_offset: int = 0):
    """Memory-bounded causal attention via online softmax over KV chunks.

    q: [B, Sq, H, Dh], k/v: [B, Skv, KV, Dh].  O(Sq * chunk) live memory
    instead of O(Sq * Skv) — required for the 32k prefill shapes.
    window > 0 restricts to a sliding window (hymba SWA layers).
    """
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = 1.0 / np.sqrt(dh)
    q = q.astype(jnp.float32) * scale
    qpos = kv_offset + jnp.arange(sq)

    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, kvh, dh).astype(jnp.float32)
    vc = v.reshape(b, n_chunks, chunk, kvh, dh).astype(jnp.float32)
    kc = jnp.moveaxis(kc, 1, 0)       # [C, B, chunk, KV, Dh]
    vc = jnp.moveaxis(vc, 1, 0)

    def step(carry, inputs):
        m, l, acc = carry              # [B,H,Sq], [B,H,Sq], [B,H,Sq,Dh]
        kblk, vblk, cidx = inputs
        kpos = cidx * chunk + jnp.arange(chunk)
        # scores: [B, H, Sq, chunk] (q heads grouped onto kv heads)
        qg = q.reshape(b, sq, kvh, rep, dh)
        s = jnp.einsum("bsgrd,bcgd->bgrsc", qg, kblk)
        s = s.reshape(b, kvh * rep, sq, chunk)
        mask = kpos[None, :] <= qpos[:, None]                  # causal
        mask &= kpos[None, :] < skv                            # padding
        if window:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrsc,bcgd->bgrsd",
                        p.reshape(b, kvh, rep, sq, chunk), vblk)
        acc_new = acc * corr[..., None] + pv.reshape(b, kvh * rep, sq, dh)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    # flash-attention-style backward: recompute scores/probs per chunk in
    # the bwd pass instead of stacking per-step residuals across the scan
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2)     # [B, Sq, H, Dh]


def _gather_paged(leaf, table):
    """Materialise the logical [B, S, KV, Dh] view of a paged cache leaf.

    leaf: [P, bs, KV, Dh] physical blocks; table: [B, NB] block ids.
    The gathered view is identical (bit for bit, at every valid
    position) to the dense row the same request would hold in a
    :class:`~repro.serve.pool.SlotPool`, so attention math downstream is
    unchanged — paging moves bytes, never bits.  Positions beyond a
    row's length read whatever the un-granted blocks hold; they are
    masked by the validity count exactly like stale dense rows.
    """
    b, nb = table.shape
    bs = leaf.shape[1]
    view = leaf[table]                       # [B, NB, bs, KV, Dh]
    return view.reshape(b, nb * bs, *leaf.shape[2:])


def _verify_attention(q, k_cache, v_cache, length, s_max):
    """Speculative-verify attention: S queries against one cache view.

    q: [B, S, H, Dh]; the cache already holds this block's KV writes at
    positions ``length .. length+S-1``.  Query j may see positions
    ``< length+1+j`` — its own entry and everything before it — and the
    drafted FUTURE entries are masked out.  Implemented as S calls to
    :func:`_decode_attention` (one per query position) inside one trace,
    so each query's softmax runs over exactly the shapes the plain
    decode path uses: accepted speculative tokens are bit-identical to
    sequential decode by construction, not by accident of einsum
    scheduling.
    """
    outs = [
        _decode_attention(q[:, j:j + 1], k_cache, v_cache,
                          jnp.minimum(length + 1 + j, s_max))
        for j in range(q.shape[1])
    ]
    return jnp.concatenate(outs, axis=1)


def _decode_attention(q, k_cache, v_cache, valid_count):
    """Single-position attention against a (possibly ring-buffer) cache.

    q: [B, 1, H, Dh].  Attention over a *set* of cached entries is order-
    invariant (RoPE already encodes absolute positions), so ring-buffer
    eviction needs no re-ordering — just a validity mask.
    """
    b, _, h, dh = q.shape
    s_max, kvh = k_cache.shape[1], k_cache.shape[2]
    rep = h // kvh
    scale = 1.0 / np.sqrt(dh)
    s = jnp.einsum("bgrd,bcgd->bgrc",
                   (q.astype(jnp.float32) * scale)[:, 0].reshape(b, kvh, rep, dh),
                   k_cache.astype(jnp.float32))       # [B, KV, rep, S]
    pos = jnp.arange(s_max)
    mask = pos[None, :] < valid_count[:, None]        # [B, S]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrc,bcgd->bgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, dh)


def apply_attention(params, x, cfg: ArchConfig, layer_idx: int,
                    positions=None, cache=None, decode: bool = False):
    """Returns (out, new_cache_entry)."""
    spec = cfg.rebranch
    b, s, d = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    window = 0 if cfg.uses_full_attention(layer_idx) else cfg.sliding_window

    # NOTE: no explicit q/k/v constraints — GSPMD propagates the projection
    # output sharding through the reshape; forcing head sharding here causes
    # involuntary remat when heads don't divide the model axis (gemma, yi).
    q = rebranch.apply_linear(params["q"], x, spec).reshape(b, s, h, dh)
    k = rebranch.apply_linear(params["k"], x, spec).reshape(b, s, kv, dh)
    v = rebranch.apply_linear(params["v"], x, spec).reshape(b, s, kv, dh)

    paged = cache is not None and "table" in cache
    if positions is None:
        if decode and cache is not None:
            # [B, S]: each row's tokens extend its own length.  S is 1
            # for plain decode (the arange term is an exact integer +0)
            # and the block width for speculative verify.
            positions = cache["length"][:, None] + jnp.arange(s)[None]
        elif cache is not None:
            # prefill CONTINUATION: tokens extend the cache at its
            # current per-row length (fresh cache -> offset 0, the plain
            # prefill path, bit for bit)
            positions = cache["length"][:, None] + jnp.arange(s)[None]
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)

    if decode:
        # s == 1: plain decode, one token per row.  s > 1: speculative
        # VERIFY — a k-token draft block per row, written entry by entry
        # (same scatter per position as k plain decode steps) and
        # attended with per-query validity, so accepted tokens are
        # bit-identical to sequential decode.  Verify requires a
        # full-horizon cache (no SWA ring: a wrap would overwrite
        # entries a rejected draft must roll back) — gated upstream by
        # ``api.supports_speculation``.
        assert cache is not None
        length = cache["length"]                               # [B]
        rows = jnp.arange(k.shape[0])
        k_cache, v_cache = cache["k"], cache["v"]
        if paged:
            # Paged KV: rows own BLOCKS, not whole horizon rows.  The
            # block table indirects each row's logical ring slot to a
            # physical (block, offset); the scatter writes one entry and
            # the gather materialises the logical view attention reads.
            # Free rows' table entries all point at the pool's trash
            # block, so their (masked, never-read) decode writes land
            # outside every live request's blocks.
            table = cache["table"]                     # [B, NB]
            bs = cache["k"].shape[1]
            s_max = table.shape[1] * bs
            for j in range(s):
                slot = (length + j) % s_max
                pb = table[rows, slot // bs]           # [B] physical block
                off = slot % bs
                k_cache = k_cache.at[pb, off].set(
                    k[:, j].astype(k_cache.dtype))
                v_cache = v_cache.at[pb, off].set(
                    v[:, j].astype(v_cache.dtype))
            k_view = _gather_paged(k_cache, table)
            v_view = _gather_paged(v_cache, table)
        else:
            s_max = cache["k"].shape[1]
            # Per-ROW ring slot: under continuous batching the rows of
            # one cache hold different sequences at different lengths,
            # so each row writes its own slot (a shared ``length[0]``
            # slot corrupts every row whose length differs from row 0's
            # — the new KV lands inside an already-valid slot and the
            # true slot stays stale).
            for j in range(s):
                slot = (length + j) % s_max   # [B] ring for SWA layers
                k_cache = k_cache.at[rows, slot].set(
                    k[:, j].astype(k_cache.dtype))
                v_cache = v_cache.at[rows, slot].set(
                    v[:, j].astype(v_cache.dtype))
            k_view, v_view = k_cache, v_cache
        if s == 1:
            valid = jnp.minimum(length + 1, s_max)
            out = _decode_attention(q, k_view, v_view, valid)
        else:
            out = _verify_attention(q, k_view, v_view, length, s_max)
        new_cache = {**cache, "k": k_cache, "v": v_cache,
                     "length": length + s}
    else:
        if paged:
            raise ValueError(
                "prefill cannot run against a paged cache (physical "
                "blocks have no per-row horizon to fill); prefill into "
                "a dense batch=1 cache and adopt the row into the "
                "paged pool (serve.pool.PagedPool.adopt)")
        if cache is not None and s < cache["k"].shape[1]:
            # Prefill against a cache: attend over the UPDATED cache view
            # (cached prefix ++ this chunk at its offset), so a prompt
            # split into chunks across scheduler ticks sees exactly the
            # keys a solo whole-prompt prefill would.  For a fresh cache
            # (offset 0) this is bit-identical to attending over the
            # chunk alone: positions beyond the chunk hold zeros and are
            # causally masked, and masked entries contribute exact zeros
            # to the online softmax.  Offset is length[0]: continuation
            # assumes uniform row lengths (admission prefills are B=1).
            offset = cache["length"][0]
            k_att = jax.lax.dynamic_update_slice_in_dim(
                cache["k"].astype(k.dtype), k, offset, axis=1)
            v_att = jax.lax.dynamic_update_slice_in_dim(
                cache["v"].astype(v.dtype), v, offset, axis=1)
            out = _chunked_causal_attention(
                q, k_att, v_att, cfg.attn_chunk, window, kv_offset=offset)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), offset, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), offset, axis=1)
            new_cache = {"k": k_cache, "v": v_cache,
                         "length": cache["length"] + s}
        else:
            out = _chunked_causal_attention(q, k, v, cfg.attn_chunk, window)
            if cache is not None:    # prompt >= horizon: SWA ring fill
                s_max = cache["k"].shape[1]
                # keep the window tail, laid out so that token t sits at
                # slot t % s_max (decode continues the ring); chunked
                # continuation never reaches here (total <= horizon).
                k_w = jnp.roll(k[:, -s_max:], s % s_max, axis=1)
                v_w = jnp.roll(v[:, -s_max:], s % s_max, axis=1)
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k_w.astype(cache["k"].dtype), 0, axis=1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v_w.astype(cache["v"].dtype), 0, axis=1)
                new_cache = {"k": k_cache, "v": v_cache,
                             "length": cache["length"] + s}
            else:
                new_cache = None

    out = out.astype(x.dtype).reshape(b, s, h * dh)
    out = rebranch.apply_linear(params["o"], out, spec,
                                t1_axes=("batch", "seq", "mlp"),
                                out_axes=("batch", "seq_sp", None))
    # seq_sp BEFORE the residual add: converts the row-parallel partial-sum
    # all-reduce into a reduce-scatter (16x less wire on a 16-way axis)
    return shard(out, "batch", "seq_sp", None), new_cache


def init_attention_cache(cfg: ArchConfig, batch: int, max_len: int,
                         layer_idx: int, dtype=jnp.bfloat16):
    """SWA layers get a ring buffer of window size; full-attention layers
    keep the whole horizon."""
    window = (0 if cfg.uses_full_attention(layer_idx)
              else cfg.sliding_window)
    s = max_len if window == 0 else min(max_len, window)
    return {
        "k": jnp.zeros((batch, s, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, s, cfg.num_kv_heads, cfg.head_dim), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def init_paged_attention_cache(cfg: ArchConfig, rows: int, n_blocks: int,
                               block_size: int, max_len: int,
                               dtype=jnp.bfloat16):
    """One layer of a PAGED KV cache: physical blocks + a block table.

    ``k``/``v`` hold ``n_blocks`` physical blocks of ``block_size``
    positions each, shared by every row; ``table`` maps (row, logical
    block) -> physical block id and is owned by the pool (the model only
    reads it).  The logical horizon per row is
    ``table.shape[1] * block_size == max_len`` — ``block_size`` must
    divide ``max_len`` so the gathered view has exactly the dense
    cache's shape (same softmax geometry = same bits).  Table entries
    are initialised to the LAST block, which the pool reserves as the
    trash block for free rows' masked decode writes.
    """
    if max_len % block_size:
        raise ValueError(
            f"block_size {block_size} does not divide max_len {max_len}; "
            f"the gathered paged view must have exactly the dense cache "
            f"shape (same attention geometry = same bits)")
    if not cfg.uses_full_attention(layer_idx=0) or cfg.sliding_window:
        raise ValueError(
            f"paged KV requires a uniform full-attention horizon; "
            f"{cfg.name!r} has sliding_window={cfg.sliding_window} "
            f"(ring caches smaller than max_len cannot share one block "
            f"table) — serve this config over a dense SlotPool")
    nb = max_len // block_size
    return {
        "k": jnp.zeros((n_blocks, block_size, cfg.num_kv_heads,
                        cfg.head_dim), dtype),
        "v": jnp.zeros((n_blocks, block_size, cfg.num_kv_heads,
                        cfg.head_dim), dtype),
        "length": jnp.zeros((rows,), jnp.int32),
        "table": jnp.full((rows, nb), n_blocks - 1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: int | None = None):
    spec = cfg.rebranch
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "gate": rebranch.init_linear(ks[0], d, ff, spec),
            "up": rebranch.init_linear(ks[1], d, ff, spec),
            "down": rebranch.init_linear(ks[2], ff, d, spec),
        }
    return {
        "up": rebranch.init_linear(ks[1], d, ff, spec),
        "down": rebranch.init_linear(ks[2], ff, d, spec),
    }


def apply_mlp(params, x, cfg: ArchConfig):
    spec = cfg.rebranch
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = rebranch.apply_linear(params["gate"], x, spec)
        u = rebranch.apply_linear(params["up"], x, spec)
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(rebranch.apply_linear(params["up"], x, spec))
    h = shard(h, "batch", "seq", "mlp")
    return rebranch.apply_linear(params["down"], h, spec,
                                 t1_axes=("batch", "seq", "mlp"),
                                 out_axes=("batch", "seq_sp", None))
