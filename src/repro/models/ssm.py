"""Mamba-1 selective SSM (falcon-mamba-7b) with ReBranch projections.

The large linear maps (in_proj, x_proj, dt_proj, out_proj) are ReBranch
layers (frozen int8 ROM trunk + trainable branch).  The recurrence itself
is element-wise — not a CiM operation — and its small parameters
(A_log, D, conv kernel, norms) stay trainable ("SRAM").

Scan: chunked parallel scan — jax.lax.scan over sequence chunks carrying
the SSM state, associative scan within a chunk.  Memory is O(B * chunk *
d_inner * d_state) instead of O(B * S * d_inner * d_state), which is what
makes the 500k-token cells lowerable.

falcon-mamba deviation from mamba-1: RMSNorm applied to dt/B/C streams
(cfg.ssm_norm).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rebranch
from repro.distributed.sharding import shard
from repro.models import layers
from repro.models.config import ArchConfig, spec_for


def init_ssm_block(key, cfg: ArchConfig, prefix: str = "blocks"):
    """prefix: the site-tree path of this block's projection sites
    (``'blocks'`` for the mamba backbone, ``'blocks.ssm'`` inside the
    hybrid) — each large projection is its own overridable site."""
    ks = jax.random.split(key, 6)
    d, di, n, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    # S4D-real initialisation for A
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    p = {
        "in_proj": rebranch.init_linear(
            ks[0], d, 2 * di, spec_for(cfg, f"{prefix}.in_proj")),
        "conv": {"sram": {
            "w": jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32)
                 / np.sqrt(cfg.d_conv),
            "b": jnp.zeros((di,), jnp.float32)}},
        "x_proj": rebranch.init_linear(
            ks[2], di, dtr + 2 * n, spec_for(cfg, f"{prefix}.x_proj")),
        "dt_proj": rebranch.init_linear(
            ks[3], dtr, di, spec_for(cfg, f"{prefix}.dt_proj"),
            use_bias=True),
        "A_log": {"sram": {"w": jnp.log(a)}},
        "D": {"sram": {"w": jnp.ones((di,), jnp.float32)}},
        "out_proj": rebranch.init_linear(
            ks[4], di, d, spec_for(cfg, f"{prefix}.out_proj")),
    }
    # dt bias init so softplus(dt) starts in [1e-3, 1e-1]
    dt_init = jnp.exp(jax.random.uniform(ks[5], (di,)) *
                      (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    p["dt_proj"]["sram"]["b"] = dt_init + jnp.log(
        -jnp.expm1(-dt_init))            # inverse softplus
    if cfg.ssm_norm:
        p["dt_norm"] = layers.init_rmsnorm(dtr)
        p["b_norm"] = layers.init_rmsnorm(n)
        p["c_norm"] = layers.init_rmsnorm(n)
    return p


def _ssm_scan_chunked(u, dt, a, b, c, d_skip, chunk: int, h0=None):
    """Selective scan  h' = exp(dt*A) h + dt*B u ;  y = C h + D u.

    u/dt: [B, S, di];  b/c: [B, S, N];  a: [di, N].
    Chunked: sequential lax.scan over S/chunk carrying h, associative scan
    inside each chunk.  Returns (y [B,S,di], h_final [B,di,N]).
    """
    bsz, s, di = u.shape
    n = a.shape[1]
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    # decay and input terms
    # da: [B, S, di, N] = exp(dt * A)   (A negative real)
    def chunk_fn(h, inp):
        u_c, dt_c, b_c, c_c = inp                      # [B, chunk, ...]
        da = jnp.exp(dt_c[..., None] * a[None, None])  # [B,ch,di,N]
        dbu = (dt_c * u_c)[..., None] * b_c[:, :, None, :]

        def assoc(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        a_acc, b_acc = jax.lax.associative_scan(assoc, (da, dbu), axis=1)
        h_all = a_acc * h[:, None] + b_acc             # [B,ch,di,N]
        y_c = jnp.einsum("bsdn,bsn->bsd", h_all, c_c)
        return h_all[:, -1], y_c

    u_ch = u.reshape(bsz, n_chunks, chunk, di).swapaxes(0, 1)
    dt_ch = dt.reshape(bsz, n_chunks, chunk, di).swapaxes(0, 1)
    b_ch = b.reshape(bsz, n_chunks, chunk, n).swapaxes(0, 1)
    c_ch = c.reshape(bsz, n_chunks, chunk, n).swapaxes(0, 1)

    h_init = (jnp.zeros((bsz, di, n), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, y = jax.lax.scan(jax.checkpoint(chunk_fn), h_init,
                             (u_ch, dt_ch, b_ch, c_ch))
    y = y.swapaxes(0, 1).reshape(bsz, n_chunks * chunk, di)[:, :s]
    return y + u[:, :s] * d_skip[None, None], h_last


def _compute_ssm_inputs(params, x_conv, cfg: ArchConfig,
                        prefix: str = "blocks"):
    di, n, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xdbc = rebranch.apply_linear(params["x_proj"], x_conv,
                                 spec_for(cfg, f"{prefix}.x_proj"))
    dt_r, b, c = jnp.split(xdbc, [dtr, dtr + n], axis=-1)
    if cfg.ssm_norm:                       # falcon-mamba
        dt_r = layers.apply_rmsnorm(params["dt_norm"], dt_r, cfg.norm_eps)
        b = layers.apply_rmsnorm(params["b_norm"], b, cfg.norm_eps)
        c = layers.apply_rmsnorm(params["c_norm"], c, cfg.norm_eps)
    dt = jax.nn.softplus(
        rebranch.apply_linear(
            params["dt_proj"], dt_r,
            spec_for(cfg, f"{prefix}.dt_proj")).astype(jnp.float32))
    a = -jnp.exp(params["A_log"]["sram"]["w"])
    return dt, a, b.astype(jnp.float32), c.astype(jnp.float32)


def apply_ssm_block(params, x, cfg: ArchConfig, cache=None, decode=False,
                    prefix: str = "blocks"):
    """Returns (out, new_cache).  cache = {conv [B,K-1,di], h [B,di,N]}."""
    bsz, s, _ = x.shape
    di = cfg.d_inner
    xz = rebranch.apply_linear(params["in_proj"], x,
                               spec_for(cfg, f"{prefix}.in_proj"))
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = shard(xi, "batch", "seq", "ssm_inner")

    conv_w = params["conv"]["sram"]["w"]                 # [K, di]
    conv_b = params["conv"]["sram"]["b"]
    k = conv_w.shape[0]

    if decode:
        assert cache is not None and s == 1
        hist = jnp.concatenate([cache["conv"], xi], axis=1)   # [B,K,di]
        x_conv = jnp.einsum("bkd,kd->bd", hist.astype(jnp.float32),
                            conv_w)[:, None] + conv_b
        x_conv = jax.nn.silu(x_conv).astype(x.dtype)
        dt, a, b, c = _compute_ssm_inputs(params, x_conv, cfg, prefix)
        h = cache["h"].astype(jnp.float32)
        da = jnp.exp(dt[:, 0, :, None] * a[None])             # [B,di,N]
        dbu = (dt[:, 0] * x_conv.astype(jnp.float32)[:, 0])[..., None] \
            * b[:, 0, None, :]
        h_new = da * h + dbu
        y = jnp.einsum("bdn,bn->bd", h_new, c[:, 0])[:, None]
        y = y + x_conv.astype(jnp.float32) * params["D"]["sram"]["w"]
        new_cache = {"conv": hist[:, 1:], "h": h_new}
    else:
        # causal depthwise conv over the sequence
        if cache is not None and "conv" in cache:
            xpad = jnp.concatenate([cache["conv"], xi], axis=1)
        else:
            xpad = jnp.pad(xi, ((0, 0), (k - 1, 0), (0, 0)))
        x_conv = sum(
            xpad[:, i:i + s].astype(jnp.float32) * conv_w[i]
            for i in range(k)) + conv_b
        x_conv = jax.nn.silu(x_conv).astype(x.dtype)
        dt, a, b, c = _compute_ssm_inputs(params, x_conv, cfg, prefix)
        h0 = cache["h"] if (cache is not None and "h" in cache) else None
        y, h_last = _ssm_scan_chunked(
            x_conv.astype(jnp.float32), dt, a, b, c,
            params["D"]["sram"]["w"], chunk=min(cfg.attn_chunk, s), h0=h0)
        new_cache = None
        if cache is not None:
            new_cache = {"conv": xpad[:, -(k - 1):] if k > 1 else
                         xpad[:, :0], "h": h_last}

    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = rebranch.apply_linear(params["out_proj"], y,
                              spec_for(cfg, f"{prefix}.out_proj"),
                              t1_axes=("batch", "seq", "mlp"),
                              out_axes=("batch", "seq_sp", None))
    return shard(y, "batch", "seq_sp", None), new_cache


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# full model (mamba backbone: norm -> ssm -> residual)
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ArchConfig):
    return {
        "ln": layers.init_rmsnorm(cfg.d_model),
        "ssm": init_ssm_block(key, cfg),
    }


def init(key, cfg: ArchConfig):
    keys = jax.random.split(key, cfg.num_layers + 2)
    if cfg.scan_layers:
        blocks = jax.vmap(lambda k: _layer_init(k, cfg))(
            jnp.stack(keys[1:cfg.num_layers + 1]))
    else:
        blocks = [_layer_init(keys[i + 1], cfg)
                  for i in range(cfg.num_layers)]
    return {
        "embed": layers.init_embedding(keys[0], cfg.vocab_size,
                                       cfg.d_model, cfg),
        "layers": blocks,
        "ln_f": layers.init_rmsnorm(cfg.d_model),
        "lm_head": rebranch.init_linear(keys[-1], cfg.d_model,
                                        cfg.vocab_size,
                                        spec_for(cfg, "lm_head")),
    }


def features(params, batch, cfg: ArchConfig):
    x = layers.apply_embedding(params["embed"], batch["tokens"], cfg)
    x = shard(x, "batch", "seq_sp", "embed")

    def fn(blk, xx):
        h, _ = apply_ssm_block(
            blk["ssm"],
            layers.apply_rmsnorm(blk["ln"], xx, cfg.norm_eps), cfg)
        return xx + h

    if cfg.scan_layers:
        body = lambda xx, blk: (
            shard(fn(blk, xx), "batch", "seq_sp", "embed"), None)
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x
    fn2 = jax.checkpoint(fn) if cfg.remat else fn
    for block in params["layers"]:
        x = shard(fn2(block, x), "batch", "seq_sp", "embed")
    return x


def apply_head(params, x, cfg: ArchConfig):
    x = layers.apply_rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return rebranch.apply_linear(params["lm_head"], x,
                                 spec_for(cfg, "lm_head"))


def forward(params, batch, cfg: ArchConfig):
    logits = apply_head(params, features(params, batch, cfg), cfg)
    return shard(logits, "batch", "seq", "vocab")


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    del max_len                            # O(1) state — the SSM advantage
    if cfg.scan_layers:
        one = init_ssm_cache(cfg, batch, dtype)
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), one)}
    return {"layers": [init_ssm_cache(cfg, batch, dtype)
                       for _ in range(cfg.num_layers)]}


def prefill(params, batch, cfg: ArchConfig, cache):
    x = layers.apply_embedding(params["embed"], batch["tokens"], cfg)
    x = shard(x, "batch", "seq_sp", "embed")

    def fn(blk, xx, lc):
        h, nc = apply_ssm_block(
            blk["ssm"],
            layers.apply_rmsnorm(blk["ln"], xx, cfg.norm_eps),
            cfg, cache=lc)
        return xx + h, nc

    if cfg.scan_layers:
        body = lambda xx, inp: fn(inp[0], xx, inp[1])
        x, new_caches = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
    else:
        new_caches = []
        for block, lc in zip(params["layers"], cache["layers"]):
            x, nc = fn(block, x, lc)
            new_caches.append(nc)
    x = layers.apply_rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = rebranch.apply_linear(params["lm_head"], x,
                                   spec_for(cfg, "lm_head"))
    return logits.astype(jnp.float32), {"layers": new_caches}


def decode_step(params, tokens, cfg: ArchConfig, cache):
    x = layers.apply_embedding(params["embed"], tokens, cfg)

    def fn(blk, xx, lc):
        h, nc = apply_ssm_block(
            blk["ssm"],
            layers.apply_rmsnorm(blk["ln"], xx, cfg.norm_eps),
            cfg, cache=lc, decode=True)
        return xx + h, nc

    if cfg.scan_layers:
        body = lambda xx, inp: fn(inp[0], xx, inp[1])
        x, new_caches = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
    else:
        new_caches = []
        for block, lc in zip(params["layers"], cache["layers"]):
            x, nc = fn(block, x, lc)
            new_caches.append(nc)
    x = layers.apply_rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = rebranch.apply_linear(params["lm_head"], x,
                                   spec_for(cfg, "lm_head"))
    return logits.astype(jnp.float32), {"layers": new_caches}
