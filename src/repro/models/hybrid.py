"""Hymba-style hybrid: parallel attention + SSM heads in every layer.

Each block computes attention and a mamba-style SSM on the same
(normalised) input in parallel; the two paths are per-path RMS-normalised,
scaled by learnable betas, and averaged (Hymba fusion).  Most layers use
sliding-window attention; cfg.full_attn_layers get global attention
(Hymba: first, middle, last).  Meta tokens are elided (noted in DESIGN.md)
— they add a constant 128-token prefix orthogonal to the CiM technique.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rebranch
from repro.distributed.sharding import shard
from repro.models import layers, ssm
from repro.models.config import ArchConfig, spec_for
from repro.models.transformer import site_cfg


def _block_init(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.init_rmsnorm(cfg.d_model),
        "attn": layers.init_attention(k1, site_cfg(cfg, "blocks.attn")),
        "ssm": ssm.init_ssm_block(k2, cfg, prefix="blocks.ssm"),
        "attn_norm": layers.init_rmsnorm(cfg.d_model),
        "ssm_norm": layers.init_rmsnorm(cfg.d_model),
        "beta": {"sram": {"w": jnp.ones((2,), jnp.float32)}},
        "ln2": layers.init_rmsnorm(cfg.d_model),
        "mlp": layers.init_mlp(k3, site_cfg(cfg, "blocks.mlp")),
    }


def _block_apply(params, x, cfg: ArchConfig, layer_idx: int,
                 cache=None, decode=False):
    h = layers.apply_rmsnorm(params["ln1"], x, cfg.norm_eps)
    attn_cache = cache.get("attn") if cache else None
    ssm_cache = cache.get("ssm") if cache else None

    a_out, new_attn = layers.apply_attention(
        params["attn"], h, site_cfg(cfg, "blocks.attn"), layer_idx,
        cache=attn_cache, decode=decode)
    s_out, new_ssm = ssm.apply_ssm_block(
        params["ssm"], h, cfg, cache=ssm_cache, decode=decode,
        prefix="blocks.ssm")

    beta = params["beta"]["sram"]["w"]
    a_out = layers.apply_rmsnorm(params["attn_norm"], a_out, cfg.norm_eps)
    s_out = layers.apply_rmsnorm(params["ssm_norm"], s_out, cfg.norm_eps)
    fused = 0.5 * (beta[0] * a_out.astype(jnp.float32)
                   + beta[1] * s_out.astype(jnp.float32)).astype(x.dtype)
    x = x + fused

    h2 = layers.apply_rmsnorm(params["ln2"], x, cfg.norm_eps)
    x = x + layers.apply_mlp(params["mlp"], h2, site_cfg(cfg, "blocks.mlp"))
    new_cache = None
    if cache is not None:
        new_cache = {"attn": new_attn, "ssm": new_ssm}
    return x, new_cache


def init(key, cfg: ArchConfig):
    keys = jax.random.split(key, cfg.num_layers + 2)
    return {
        "embed": layers.init_embedding(keys[0], cfg.vocab_size,
                                       cfg.d_model, cfg),
        "layers": [_block_init(keys[i + 1], cfg)
                   for i in range(cfg.num_layers)],
        "ln_f": layers.init_rmsnorm(cfg.d_model),
        "lm_head": rebranch.init_linear(keys[-1], cfg.d_model,
                                        cfg.vocab_size,
                                        spec_for(cfg, "lm_head")),
    }


def features(params, batch, cfg: ArchConfig):
    x = layers.apply_embedding(params["embed"], batch["tokens"], cfg)
    x = shard(x, "batch", "seq_sp", "embed")
    for i, block in enumerate(params["layers"]):
        fn = lambda p, xx, _i=i: _block_apply(p, xx, cfg, _i)[0]
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x = shard(fn(block, x), "batch", "seq_sp", "embed")
    return x


def apply_head(params, x, cfg: ArchConfig):
    x = layers.apply_rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return rebranch.apply_linear(params["lm_head"], x,
                                 spec_for(cfg, "lm_head"))


def forward(params, batch, cfg: ArchConfig):
    logits = apply_head(params, features(params, batch, cfg), cfg)
    return shard(logits, "batch", "seq", "vocab")


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """SWA layers keep a window-sized linear buffer; full-attn layers keep
    the whole horizon; SSM state is O(1) — this is what makes long_500k
    lowerable for the hybrid."""
    caches = [{
        "attn": layers.init_attention_cache(cfg, batch, max_len, i, dtype),
        "ssm": ssm.init_ssm_cache(cfg, batch, dtype),
    } for i in range(cfg.num_layers)]
    return {"layers": caches}


def prefill(params, batch, cfg: ArchConfig, cache):
    x = layers.apply_embedding(params["embed"], batch["tokens"], cfg)
    x = shard(x, "batch", "seq_sp", "embed")
    new_caches = []
    for i, block in enumerate(params["layers"]):
        x, nc = _block_apply(block, x, cfg, i, cache=cache["layers"][i])
        new_caches.append(nc)
    x = layers.apply_rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = rebranch.apply_linear(params["lm_head"], x,
                                   spec_for(cfg, "lm_head"))
    return logits.astype(jnp.float32), {"layers": new_caches}


def decode_step(params, tokens, cfg: ArchConfig, cache):
    x = layers.apply_embedding(params["embed"], tokens, cfg)
    new_caches = []
    for i, block in enumerate(params["layers"]):
        x, nc = _block_apply(block, x, cfg, i, cache=cache["layers"][i],
                             decode=True)
        new_caches.append(nc)
    x = layers.apply_rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = rebranch.apply_linear(params["lm_head"], x,
                                   spec_for(cfg, "lm_head"))
    return logits.astype(jnp.float32), {"layers": new_caches}
