"""Decoder-only LM: dense / GQA / VLM / multi-codebook-audio families.

One implementation covers musicgen-large (4-codebook audio tokens),
qwen2-vl-2b (M-RoPE + vision-embedding stub), yi-34b, qwen1.5-32b
(QKV bias), gemma-2b (GeGLU, head_dim 256, MQA), deepseek-67b.

API (shared by all families in the zoo):
  init(key, cfg)                                   -> params
  forward(params, batch, cfg)                      -> logits
  prefill(params, batch, cfg, cache)               -> (logits, cache)
  decode_step(params, tokens, cfg, cache)          -> (logits, cache)
  init_cache(cfg, batch, max_len)                  -> cache
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import dataclasses

from repro.core import rebranch
from repro.distributed.sharding import shard
from repro.models import layers
from repro.models.config import ArchConfig, spec_for


def site_cfg(cfg: ArchConfig, site: str) -> ArchConfig:
    """cfg with the resolved spec for ``site`` as its config-wide rebranch.

    The per-site mapping hook for components whose internals consult
    ``cfg.rebranch`` directly (attention, MLP, MoE, SSM blocks): the
    caller resolves the component's site through ``spec_for`` — which
    honours ancestor-prefix overrides, so a ``'blocks'`` override still
    governs every ``blocks.*`` sub-site — and hands the component a cfg
    carrying that spec.  scan-over-layers keeps blocks uniform across
    depth, so block sub-sites name components, not layer indices."""
    spec = spec_for(cfg, site)
    if spec is cfg.rebranch:
        return cfg
    return dataclasses.replace(cfg, rebranch=spec)


def _block_init(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    block = {
        "ln1": layers.init_rmsnorm(cfg.d_model),
        "attn": layers.init_attention(k1, site_cfg(cfg, "blocks.attn")),
        "ln2": layers.init_rmsnorm(cfg.d_model),
    }
    if cfg.family == "moe":
        from repro.models import moe
        block["moe"] = moe.init_moe_block(k2, site_cfg(cfg, "blocks.moe"))
    else:
        block["mlp"] = layers.init_mlp(k2, site_cfg(cfg, "blocks.mlp"))
    return block


def _block_apply(params, x, cfg: ArchConfig, layer_idx: int,
                 positions=None, cache=None, decode=False):
    h, new_cache = layers.apply_attention(
        params["attn"], layers.apply_rmsnorm(params["ln1"], x, cfg.norm_eps),
        site_cfg(cfg, "blocks.attn"), layer_idx,
        positions=positions, cache=cache, decode=decode)
    x = x + h
    h2 = layers.apply_rmsnorm(params["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        from repro.models import moe
        h2 = moe.apply_moe_block(params["moe"], h2,
                                 site_cfg(cfg, "blocks.moe"))
    else:
        h2 = layers.apply_mlp(params["mlp"], h2, site_cfg(cfg, "blocks.mlp"))
    return x + h2, new_cache


def init(key, cfg: ArchConfig):
    keys = jax.random.split(key, cfg.num_layers + 3)
    if cfg.scan_layers:
        # stacked per-layer params (leading L dim) -> lax.scan over layers:
        # compile time is O(1) in depth (deepseek-67b: 95 layers)
        blocks = jax.vmap(lambda k: _block_init(k, cfg))(
            jnp.stack(keys[1:cfg.num_layers + 1]))
    else:
        blocks = [_block_init(keys[i + 1], cfg)
                  for i in range(cfg.num_layers)]
    params = {
        "embed": layers.init_embedding(keys[0], cfg.vocab_size,
                                       cfg.d_model, cfg),
        "layers": blocks,
        "ln_f": layers.init_rmsnorm(cfg.d_model),
    }
    if cfg.num_codebooks:      # musicgen: per-codebook readout heads
        params["codebook_head"] = rebranch.init_linear(
            keys[-1], cfg.d_model, cfg.num_codebooks * cfg.vocab_size,
            spec_for(cfg, "codebook_head"))
    elif not cfg.tie_embeddings:
        params["lm_head"] = rebranch.init_linear(
            keys[-1], cfg.d_model, cfg.vocab_size, spec_for(cfg, "lm_head"))
    return params


def _embed_inputs(params, batch, cfg: ArchConfig):
    """tokens [B,S] (or [B,S,Q] for multi-codebook) and/or precomputed
    frontend embeddings [B,S,d] (vision/audio stub)."""
    if "embeds" in batch:                  # modality stub path
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        if "tokens" in batch:
            x = x + _token_embed(params, batch["tokens"], cfg)
        return x
    return _token_embed(params, batch["tokens"], cfg)


def _token_embed(params, tokens, cfg: ArchConfig):
    if cfg.num_codebooks and tokens.ndim == 3:   # [B, S, Q] codebooks
        embs = layers.apply_embedding(
            params["embed"],
            tokens[..., 0] + 0, cfg)
        for q in range(1, cfg.num_codebooks):
            embs = embs + layers.apply_embedding(
                params["embed"], tokens[..., q], cfg)
        return embs
    return layers.apply_embedding(params["embed"], tokens, cfg)


def apply_head(params, x, cfg: ArchConfig):
    """ln_f + readout projection on [..., d] -> [..., V] / [..., Q, V]."""
    x = layers.apply_rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.num_codebooks:
        logits = rebranch.apply_linear(params["codebook_head"], x,
                                       spec_for(cfg, "codebook_head"))
        logits = logits.reshape(*logits.shape[:-1], cfg.num_codebooks,
                                cfg.vocab_size)
    elif cfg.tie_embeddings:
        logits = layers.embedding_as_logits(params["embed"], x, cfg)
    else:
        logits = rebranch.apply_linear(params["lm_head"], x,
                                       spec_for(cfg, "lm_head"))
    return logits


def _readout(params, x, cfg: ArchConfig):
    return shard(apply_head(params, x, cfg), "batch", "seq", "vocab")


def features(params, batch, cfg: ArchConfig):
    """Forward through the blocks only (pre-ln_f hidden states)."""
    x = _embed_inputs(params, batch, cfg)
    x = shard(x, "batch", "seq_sp", "embed")
    positions = batch.get("positions")
    if cfg.scan_layers:
        def body(xx, block):
            out = _block_apply(block, xx, cfg, 0, positions=positions)[0]
            return shard(out, "batch", "seq_sp", "embed"), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x
    for i, block in enumerate(params["layers"]):
        fn = lambda p, xx, pos, _i=i: _block_apply(p, xx, cfg, _i,
                                                   positions=pos)[0]
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x = shard(fn(block, x, positions), "batch", "seq_sp", "embed")
    return x


def forward(params, batch, cfg: ArchConfig):
    """Full-sequence forward (training).  cfg.remat checkpoints each block
    so train-step live memory is one residual stream per layer boundary."""
    return _readout(params, features(params, batch, cfg), cfg)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    if cfg.scan_layers:   # stacked: leading L dim on every cache leaf
        one = layers.init_attention_cache(cfg, batch, max_len, 0, dtype)
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)),
            one)}
    return {
        "layers": [layers.init_attention_cache(cfg, batch, max_len, i, dtype)
                   for i in range(cfg.num_layers)],
    }


def init_paged_cache(cfg: ArchConfig, rows: int, n_blocks: int,
                     block_size: int, max_len: int, dtype=jnp.bfloat16):
    """Paged KV cache: shared physical blocks + per-row block tables.

    Same tree shape as :func:`init_cache` (one dict per layer, stacked
    under ``scan_layers``) but each layer carries ``n_blocks`` physical
    [block_size, KV, Dh] blocks plus a [rows, max_len/block_size] block
    table instead of dense [rows, max_len] KV rows.  Block tables are
    owned by :class:`repro.serve.pool.PagedPool`.
    """
    if cfg.scan_layers:
        one = layers.init_paged_attention_cache(
            cfg, rows, n_blocks, block_size, max_len, dtype)
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)),
            one)}
    return {
        "layers": [layers.init_paged_attention_cache(
            cfg, rows, n_blocks, block_size, max_len, dtype)
            for _ in range(cfg.num_layers)],
    }


def prefill(params, batch, cfg: ArchConfig, cache):
    x = _embed_inputs(params, batch, cfg)
    x = shard(x, "batch", "seq_sp", "embed")
    positions = batch.get("positions")
    if cfg.scan_layers:
        def body(xx, inp):
            block, lc = inp
            out, nc = _block_apply(block, xx, cfg, 0, positions=positions,
                                   cache=lc)
            return shard(out, "batch", "seq_sp", "embed"), nc
        x, new_caches = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        logits = _readout(params, x[:, -1:, :], cfg)
        return logits, {"layers": new_caches}
    new_layer_caches = []
    for i, block in enumerate(params["layers"]):
        x, lc = _block_apply(block, x, cfg, i, positions=positions,
                             cache=cache["layers"][i])
        new_layer_caches.append(lc)
    logits = _readout(params, x[:, -1:, :], cfg)
    return logits, {"layers": new_layer_caches}


def decode_step(params, tokens, cfg: ArchConfig, cache):
    """One token per sequence against the KV cache. tokens: [B,1] (or
    [B,1,Q] multi-codebook)."""
    x = _token_embed(params, tokens, cfg)
    x = shard(x, "batch", None, "embed")
    if cfg.scan_layers:
        def body(xx, inp):
            block, lc = inp
            out, nc = _block_apply(block, xx, cfg, 0, cache=lc, decode=True)
            return out, nc
        x, new_caches = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        return _readout(params, x, cfg), {"layers": new_caches}
    new_layer_caches = []
    for i, block in enumerate(params["layers"]):
        x, lc = _block_apply(block, x, cfg, i,
                             cache=cache["layers"][i], decode=True)
        new_layer_caches.append(lc)
    logits = _readout(params, x, cfg)
    return logits, {"layers": new_layer_caches}


def verify_step(params, tokens, cfg: ArchConfig, cache):
    """Speculative VERIFY: a k-token block per sequence in one pass.

    tokens: [B, k] — per row, the last accepted token followed by the
    first k-1 drafted tokens.  Returns logits [B, k, V]: position i's
    argmax is the TRUE next token after input i (the decode path writes
    each token's KV before attending, with per-query validity masks), so
    the caller accepts the longest drafted prefix that matches and takes
    the first mismatch's correction for free — bit-identical to k plain
    ``decode_step`` calls on the accepted prefix.  The cache comes back
    advanced by k on every row; the serving pool rolls rejected tail
    entries back (``rollback``).  The model body IS ``decode_step`` —
    every layer is seq-width generic; only the deploy-surface geometry
    check distinguishes the two.
    """
    return decode_step(params, tokens, cfg, cache)
