"""Architecture configuration for every supported model family."""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.rebranch import ReBranchSpec


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    mlp_type: str = "swiglu"       # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False            # qwen2-vl M-RoPE (3-section rotary)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_group_size: int = 1024     # dispatch group (memory/locality knob)
    moe_capacity_factor: float = 1.25
    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)
    ssm_norm: bool = False         # falcon-mamba: RMSNorm on dt/B/C
    # --- hybrid (hymba) ---
    sliding_window: int = 0        # 0 -> full attention everywhere
    full_attn_layers: tuple = ()   # layer idxs with global attention
    # --- multi-codebook audio (musicgen) ---
    num_codebooks: int = 0
    # --- frontend stub ---
    frontend: str = "none"         # none | vision | audio
    # --- technique ---
    rebranch: ReBranchSpec = dataclasses.field(default_factory=ReBranchSpec)
    # Per-site mapping overrides: ((address, ReBranchSpec), ...) resolved
    # by spec_for() with longest-prefix matching.  Addresses live in the
    # family's enumerated site tree (repro.plan.sites): leaf sites like
    # 'blocks.attn' / 'blocks.ssm.in_proj' / 'lm_head' or ancestor
    # prefixes like 'blocks', so e.g. the readout can stay SRAM-trainable
    # while the trunk is ROM, or one component can run another engine —
    # the paper's Fig. 12 per-layer ROM/SRAM area map.  Normally built by
    # repro.deploy.compile_model from a repro.plan.PlacementPlan.
    rebranch_overrides: tuple = ()
    # --- numerics ---
    dtype: Any = "bfloat16"
    remat: bool = True             # per-block activation checkpointing (train)
    # --- attention chunking (memory-bounded attention) ---
    attn_chunk: int = 1024

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if self.dt_rank == 0 and self.ssm_state:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def scan_layers(self) -> bool:
        """Stacked-params lax.scan over layers (compile time O(1) in L).
        Hybrid archs keep a python loop: per-layer SWA window / cache
        shapes are heterogeneous."""
        return self.family != "hybrid"

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (long_500k eligibility)."""
        return self.family in ("ssm", "hybrid")

    def uses_full_attention(self, layer_idx: int) -> bool:
        if self.sliding_window == 0:
            return True
        return layer_idx in self.full_attn_layers


def spec_for(cfg, site: str) -> ReBranchSpec:
    """The ReBranchSpec governing one named parameter group (``site``).

    Works for any config carrying ``rebranch`` + ``rebranch_overrides``
    (ArchConfig and models.cnn.CNNConfig).  Sites are dotted paths in the
    family's site tree (see ``repro.plan.sites``); an override addresses
    either a leaf site exactly or an ancestor prefix (``'blocks'`` governs
    ``'blocks.attn'``, ``'blocks.mlp'``, ...).  The LONGEST matching
    override wins; unoverridden sites fall back to the config-wide spec.

    Validation happens where the enumerated site tree is known —
    ``repro.plan.PlacementPlan`` / ``repro.deploy.compile_model`` reject
    addresses outside the tree; this lookup stays a thin trace-time
    resolver.
    """
    return resolve_override(getattr(cfg, "rebranch_overrides", ()),
                            site, cfg.rebranch)


def resolve_override(entries, site: str, default):
    """Longest-prefix resolution over ((address, spec), ...) entries.

    THE one resolver — ``spec_for`` (trace time) and
    ``repro.plan.PlacementPlan.spec`` (plan time) both call it, so a
    plan can never disagree with what the model actually traces.
    """
    best, best_len = None, -1
    for s, spec in entries:
        if (s == site or site.startswith(s + ".")) and len(s) > best_len:
            best, best_len = spec, len(s)
    return default if best is None else best

