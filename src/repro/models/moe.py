"""Mixture-of-Experts block (granite-moe, qwen2-moe) with ReBranch experts.

Dispatch is the TPU-standard grouped capacity scheme (MaxText-style):
tokens are split into groups; within each group every token's top-k
experts get a capacity slot (priority = token order); one-hot dispatch/
combine einsums move tokens to/from the stacked expert computation.

ReBranch on experts: stacked trunk weights [E, d_in, d_out] are frozen
int8 ROM; the branch shares the fixed compress/decompress sketches across
experts (they are oblivious projections) and keeps a per-expert trainable
core [E, d_in/D, d_out/U] — so >90% of MoE parameters are ROM, matching
the paper's budget.  The router is tiny and stays trainable ("SRAM").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.distributed.sharding import shard
from repro.models.config import ArchConfig


# ---------------------------------------------------------------------------
# stacked ReBranch expert linear
# ---------------------------------------------------------------------------

def init_expert_linear(key, n_exp: int, d_in: int, d_out: int, spec):
    kw, kc, ku = jax.random.split(key, 3)
    w = jax.random.normal(kw, (n_exp, d_in, d_out), jnp.float32) / np.sqrt(d_in)
    if not spec.enabled:            # SRAM residency: plain trainable stack
        return {"sram": {"w": w}}
    absmax = jnp.max(jnp.abs(w), axis=1, keepdims=True)        # [E,1,out]
    w_scale = jnp.maximum(absmax, 1e-8) / 127.0
    w_q = jnp.clip(jnp.round(w / w_scale), -127, 127).astype(jnp.int8)
    d_c = max(1, d_in // spec.d_ratio)
    d_u = max(1, d_out // spec.u_ratio)
    return {
        "rom": {
            "w_q": w_q, "w_scale": w_scale.astype(spec.param_dtype),
            "C": jax.random.normal(kc, (d_in, d_c), spec.param_dtype)
                 / np.sqrt(d_in),
            "U": jax.random.normal(ku, (d_u, d_out), spec.param_dtype)
                 / np.sqrt(d_u),
        },
        "sram": {"core": jnp.zeros((n_exp, d_c, d_u), spec.param_dtype)},
    }


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _stacked_trunk_matmul(x, w_q, w_scale):
    """y[e] = quant(x[e]) @ w_q[e] * scales — int8 MXU path, STE backward."""
    x_q, sx = quant.quantize_activations(x)                    # [E,C,d]
    out = jax.lax.dot_general(
        x_q, w_q, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    return (out * sx * w_scale.astype(jnp.float32)).astype(x.dtype)


def _stm_fwd(x, w_q, w_scale):
    return _stacked_trunk_matmul(x, w_q, w_scale), (w_q, w_scale)


def _stm_bwd(res, g):
    w_q, w_scale = res
    w_deq = w_q.astype(g.dtype) * w_scale.astype(g.dtype)      # [E,d,f]
    dx = jnp.einsum("ecf,edf->ecd", g, w_deq)
    zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, zero(w_q), zero(w_scale)


_stacked_trunk_matmul.defvjp(_stm_fwd, _stm_bwd)


def apply_expert_linear(params, x):
    """x: [E, C, d_in] -> [E, C, d_out] (reassociated branch epilogue —
    see core.rebranch.apply_linear).  SRAM-resident stacks (no ROM image;
    'blocks.moe' mapped to SRAM) are a plain batched matmul."""
    if "rom" not in params:
        return jnp.einsum("ecd,edf->ecf", x,
                          params["sram"]["w"].astype(x.dtype))
    rom, sram = params["rom"], params["sram"]
    y = _stacked_trunk_matmul(x, rom["w_q"], rom["w_scale"])
    t1 = x @ rom["C"].astype(x.dtype)                           # [E,C,dc]
    cu = jnp.einsum("edu,uf->edf", sram["core"].astype(x.dtype),
                    rom["U"].astype(x.dtype))                   # [E,dc,f]
    return y + jnp.einsum("ecd,edf->ecf", t1, cu)


# ---------------------------------------------------------------------------
# MoE block
# ---------------------------------------------------------------------------

def init_moe_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    spec = cfg.rebranch
    d, ff, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    p = {
        "router": {"sram": {
            "w": jax.random.normal(ks[0], (d, e), jnp.float32) / np.sqrt(d)}},
        "experts": {
            "gate": init_expert_linear(ks[1], e, d, ff, spec),
            "up": init_expert_linear(ks[2], e, d, ff, spec),
            "down": init_expert_linear(ks[3], e, ff, d, spec),
        },
    }
    if cfg.num_shared_experts:
        from repro.models import layers
        shared_ff = cfg.num_shared_experts * (cfg.moe_d_ff or cfg.d_ff)
        p["shared"] = layers.init_mlp(ks[4], cfg, d_ff=shared_ff)
        p["shared_gate"] = {"sram": {
            "w": jax.random.normal(ks[5], (d, 1), jnp.float32) / np.sqrt(d)}}
    return p


def _capacity(cfg: ArchConfig) -> int:
    g, k, e = cfg.moe_group_size, cfg.num_experts_per_tok, cfg.num_experts
    c = int(np.ceil(g * k * cfg.moe_capacity_factor / e))
    return max(4, -(-c // 4) * 4)          # multiple of 4


def apply_moe_block(params, x, cfg: ArchConfig):
    b, s, d = x.shape
    t = b * s
    g = min(cfg.moe_group_size, t)
    n_groups = -(-t // g)
    pad = n_groups * g - t
    xf = x.reshape(t, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xg = xf.reshape(n_groups, g, d)
    xg = shard(xg, "batch", None, "embed")

    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = _capacity(cfg)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"]["sram"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                      # [G,g,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    dispatch = jnp.zeros((n_groups, g, e, cap), jnp.bfloat16)
    combine = jnp.zeros((n_groups, g, e, cap), jnp.float32)
    counts = jnp.zeros((n_groups, e), jnp.int32)
    for j in range(k):
        oh = jax.nn.one_hot(idx[..., j], e, dtype=jnp.int32)  # [G,g,E]
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        pos_j = jnp.sum(pos * oh, axis=-1)                    # [G,g]
        keep = (pos_j < cap).astype(jnp.float32)
        slot = jax.nn.one_hot(pos_j, cap, dtype=jnp.float32)  # [G,g,C]
        d_j = (oh.astype(jnp.float32)[..., None] * slot[:, :, None, :]
               * keep[..., None, None])
        dispatch = dispatch + d_j.astype(jnp.bfloat16)
        combine = combine + d_j * gates[..., j, None, None]
        counts = counts + jnp.sum(oh, axis=1)

    dispatch = shard(dispatch, "batch", None, "expert", None)
    combine = shard(combine, "batch", None, "expert", None)

    # [G,g,E,C] x [G,g,d] -> [E, G*C, d].  CRITICAL: the dispatched-slot
    # dim (G*C) must stay sharded over the data axis — leaving it
    # replicated makes every device compute the whole fleet's expert
    # branch (HLO showed 1.6e15 replicated flops + 3.8 TB all-gathers).
    x_exp = jnp.einsum("gtec,gtd->egcd", dispatch,
                       xg.astype(jnp.bfloat16))
    x_exp = x_exp.reshape(e, n_groups * cap, d).astype(x.dtype)
    x_exp = shard(x_exp, "expert", "batch", "embed")

    hg = apply_expert_linear(params["experts"]["gate"], x_exp)
    hu = apply_expert_linear(params["experts"]["up"], x_exp)
    h = jax.nn.silu(hg) * hu
    h = shard(h, "expert", "batch", "expert_mlp")
    h = apply_expert_linear(params["experts"]["down"], h)
    h = shard(h, "expert", "batch", "embed")

    h = h.reshape(e, n_groups, cap, d)
    y = jnp.einsum("gtec,egcd->gtd", combine,
                   h.astype(jnp.float32)).astype(x.dtype)
    y = y.reshape(n_groups * g, d)[:t].reshape(b, s, d)

    if "shared" in params:
        from repro.models import layers
        sh = layers.apply_mlp(params["shared"], x, cfg)
        sg = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x.astype(jnp.float32),
                       params["shared_gate"]["sram"]["w"]))
        y = y + (sh * sg.astype(x.dtype))
    return shard(y, "batch", "seq", None)


def aux_load_balance_loss(params, x, cfg: ArchConfig):
    """Switch-style auxiliary loss (exported for the training loop)."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"]["sram"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    frac = jnp.mean(jax.nn.one_hot(idx, cfg.num_experts), axis=(0, 1, 2))
    imp = jnp.mean(probs, axis=(0, 1))
    return cfg.num_experts * jnp.sum(frac * imp)
