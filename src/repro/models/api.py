"""Family-dispatched model API: one interface for every architecture.

  init(key, cfg)                          -> params
  forward(params, batch, cfg)             -> logits          (train)
  prefill(params, batch, cfg, cache)      -> (logits, cache) (serve)
  decode_step(params, tokens, cfg, cache) -> (logits, cache) (serve)
  init_cache(cfg, batch, max_len)         -> cache

DEPRECATED as a user entrypoint: prefer ``repro.deploy.compile_model``,
which resolves the TrunkEngine and the per-layer ROM/SRAM mapping once
and returns these same functions bound to the resolved config.  The free
functions stay as thin shims (deploy and the remaining callers route
through them) and behave identically for configs without overrides.
"""

from __future__ import annotations

from repro.models import hybrid, ssm, transformer
from repro.models.config import ArchConfig

_FAMILY = {
    "dense": transformer, "vlm": transformer, "audio": transformer,
    "moe": transformer,            # moe block dispatched inside transformer
    "ssm": ssm,
    "hybrid": hybrid,
}


def _mod(cfg: ArchConfig):
    return _FAMILY[cfg.family]


def init(key, cfg: ArchConfig):
    return _mod(cfg).init(key, cfg)


def forward(params, batch, cfg: ArchConfig):
    return _mod(cfg).forward(params, batch, cfg)


def features(params, batch, cfg: ArchConfig):
    return _mod(cfg).features(params, batch, cfg)


def apply_head(params, x, cfg: ArchConfig):
    return _mod(cfg).apply_head(params, x, cfg)


def prefill(params, batch, cfg: ArchConfig, cache):
    return _mod(cfg).prefill(params, batch, cfg, cache)


def decode_step(params, tokens, cfg: ArchConfig, cache):
    return _mod(cfg).decode_step(params, tokens, cfg, cache)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    import jax.numpy as jnp
    dtype = dtype or jnp.bfloat16
    return _mod(cfg).init_cache(cfg, batch, max_len, dtype)


def cache_geometry(cfg: ArchConfig, cache) -> tuple[int, int | None]:
    """(batch, horizon) a serve cache was built for.

    Works on the cache TREE (shapes only, jit-tracer safe).  Every cache
    leaf carries batch at axis 0 — axis 1 under scan-stacked layers,
    where leaves gain a leading L dim.  The horizon is the largest K/V
    sequence axis across layers (full-attention layers hold ``max_len``;
    SWA layers only their window); ``None`` for attention-free (O(1)
    state) families, whose horizon is unbounded.
    """
    import jax
    axis = 1 if cfg.scan_layers else 0
    leaves = jax.tree.leaves(cache)
    if not leaves:
        raise ValueError("empty cache tree")
    batch = leaves[0].shape[axis]
    if cfg.is_attention_free:
        return batch, None
    # K/V leaves are [(L,) B, S, KV, Dh] — the only rank-(4+axis) leaves
    # (ssm state inside hybrids is rank 3, lengths rank 1+axis)
    kv = [leaf.shape[1 + axis] for leaf in leaves
          if leaf.ndim == 4 + axis]
    return batch, max(kv)
