"""Family-dispatched model API: one interface for every architecture.

  init(key, cfg)                          -> params
  forward(params, batch, cfg)             -> logits          (train)
  prefill(params, batch, cfg, cache)      -> (logits, cache) (serve)
  decode_step(params, tokens, cfg, cache) -> (logits, cache) (serve)
  init_cache(cfg, batch, max_len)         -> cache

DEPRECATED as a user entrypoint: prefer ``repro.deploy.compile_model``,
which resolves the TrunkEngine and the per-layer ROM/SRAM mapping once
and returns these same functions bound to the resolved config.  The free
functions stay as thin shims (deploy and the remaining callers route
through them) and behave identically for configs without overrides.
"""

from __future__ import annotations

from repro.models import hybrid, ssm, transformer
from repro.models.config import ArchConfig

_FAMILY = {
    "dense": transformer, "vlm": transformer, "audio": transformer,
    "moe": transformer,            # moe block dispatched inside transformer
    "ssm": ssm,
    "hybrid": hybrid,
}


def _mod(cfg: ArchConfig):
    return _FAMILY[cfg.family]


def init(key, cfg: ArchConfig):
    return _mod(cfg).init(key, cfg)


def forward(params, batch, cfg: ArchConfig):
    return _mod(cfg).forward(params, batch, cfg)


def features(params, batch, cfg: ArchConfig):
    return _mod(cfg).features(params, batch, cfg)


def apply_head(params, x, cfg: ArchConfig):
    return _mod(cfg).apply_head(params, x, cfg)


def prefill(params, batch, cfg: ArchConfig, cache):
    return _mod(cfg).prefill(params, batch, cfg, cache)


def decode_step(params, tokens, cfg: ArchConfig, cache):
    return _mod(cfg).decode_step(params, tokens, cfg, cache)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    import jax.numpy as jnp
    dtype = dtype or jnp.bfloat16
    return _mod(cfg).init_cache(cfg, batch, max_len, dtype)


def supports_paging(cfg: ArchConfig) -> bool:
    """Whether the family can serve decode through a paged KV cache.

    Paging indirects KV rows through block tables, which requires every
    sequence-mixing layer to keep an attention cache with one uniform
    full-attention horizon: ssm state is O(1) (nothing to page), hybrid
    mixes ssm state with per-layer SWA windows, and SWA rings smaller
    than ``max_len`` cannot share one block table.
    """
    return (_mod(cfg) is transformer and cfg.sliding_window == 0)


def supports_speculation(cfg: ArchConfig) -> bool:
    """Whether the family can serve speculative (draft/verify) decode.

    Verify writes k KV entries per row and must be able to UNDO the
    rejected tail by truncating the row's length: that requires every
    sequence-mixing layer to keep a full-horizon attention cache.  SWA
    rings can wrap within a k-block (the overwritten entry is
    unrecoverable) and ssm/hybrid recurrent state cannot rewind at all.
    The predicate is currently the same as :func:`supports_paging`, for
    the same structural reason (uniform full-attention horizon).
    """
    return (_mod(cfg) is transformer and cfg.sliding_window == 0)


def verify_step(params, tokens, cfg: ArchConfig, cache):
    """Speculative verify: k-token block decode (see
    ``transformer.verify_step``).  Raises for families that cannot
    speculate (:func:`supports_speculation`)."""
    if not supports_speculation(cfg):
        raise ValueError(
            f"{cfg.name!r} (family {cfg.family!r}, sliding_window="
            f"{cfg.sliding_window}) cannot run speculative verify: "
            f"rolling back rejected drafts needs a full-horizon "
            f"attention cache (ssm/hybrid recurrent state cannot "
            f"rewind; SWA rings overwrite entries a rollback would "
            f"need)")
    return _mod(cfg).verify_step(params, tokens, cfg, cache)


def draft_config(cfg: ArchConfig) -> ArchConfig:
    """The branch-only DRAFT variant of ``cfg`` for speculative decode.

    Every ReBranch-enabled site gets ``trunk_skip=True``: its ROM trunk
    matmul is skipped and only the SRAM branch runs (~1/compression of
    the FLOPs, see ``core.rebranch``).  SRAM-resident sites
    (``enabled=False`` under the PlacementPlan's residency map) are
    plain trainable linears and run in full — they are the cheap part by
    placement.  The draft model shares the verify model's params tree
    verbatim (``trunk_skip`` is control flow, not weights), so a draft
    forward needs no extra memory and scenario hot-swaps apply to both
    at once.
    """
    import dataclasses

    def skip(spec):
        if not spec.enabled or spec.trunk_skip:
            return spec
        return dataclasses.replace(spec, trunk_skip=True)

    return dataclasses.replace(
        cfg, rebranch=skip(cfg.rebranch),
        rebranch_overrides=tuple(
            (site, skip(spec))
            for site, spec in getattr(cfg, "rebranch_overrides", ())))


def supports_chunked_prefill(cfg: ArchConfig) -> bool:
    """Whether prefill may be split into chunks across an existing cache.

    Chunked prefill replays the prompt through ``prefill`` with the
    partially-filled cache and explicit positions; the attention layers
    then attend over the cached prefix.  That works for every family
    whose sequence mixing is attention-with-KV-cache (the transformer
    module).  SSM layers carry recurrent + conv state that ``prefill``
    rebuilds from position 0 each call, so ssm/hybrid prompts must
    prefill whole.
    """
    return _mod(cfg) is transformer


def init_paged_cache(cfg: ArchConfig, rows: int, n_blocks: int,
                     block_size: int, max_len: int, dtype=None):
    """Paged KV cache (see ``transformer.init_paged_cache``).

    Raises for families that cannot page (:func:`supports_paging`).
    """
    import jax.numpy as jnp
    if not supports_paging(cfg):
        raise ValueError(
            f"{cfg.name!r} (family {cfg.family!r}, sliding_window="
            f"{cfg.sliding_window}) cannot serve through a paged KV "
            f"cache; use init_cache + a dense SlotPool")
    dtype = dtype or jnp.bfloat16
    return _mod(cfg).init_paged_cache(cfg, rows, n_blocks, block_size,
                                      max_len, dtype)


def cache_geometry(cfg: ArchConfig, cache) -> tuple[int, int | None]:
    """(batch, horizon) a serve cache was built for.

    Works on the cache TREE (shapes only, jit-tracer safe).  Every cache
    leaf carries batch at axis 0 — axis 1 under scan-stacked layers,
    where leaves gain a leading L dim.  The horizon is the largest K/V
    sequence axis across layers (full-attention layers hold ``max_len``;
    SWA layers only their window); ``None`` for attention-free (O(1)
    state) families, whose horizon is unbounded.

    Paged caches (leaves carrying a ``table`` entry, see
    :func:`init_paged_cache`) report their LOGICAL geometry: batch is
    the block-table row count and the horizon is
    ``table_width * block_size`` — what the gathered attention view
    holds, not the physical block count.
    """
    import jax
    axis = 1 if cfg.scan_layers else 0
    first = _first_layer(cache)
    if isinstance(first, dict) and "table" in first:
        table, k = first["table"], first["k"]          # [(L,) B, NB]
        return table.shape[axis], table.shape[-1] * k.shape[axis + 1]
    leaves = jax.tree.leaves(cache)
    if not leaves:
        raise ValueError("empty cache tree")
    batch = leaves[0].shape[axis]
    if cfg.is_attention_free:
        return batch, None
    # K/V leaves are [(L,) B, S, KV, Dh] — the only rank-(4+axis) leaves
    # (ssm state inside hybrids is rank 3, lengths rank 1+axis)
    kv = [leaf.shape[1 + axis] for leaf in leaves
          if leaf.ndim == 4 + axis]
    return batch, max(kv)


def _first_layer(cache):
    """The first per-layer cache dict (the stacked dict under scan)."""
    if not isinstance(cache, dict):
        return None
    layers = cache.get("layers")
    if isinstance(layers, (list, tuple)):
        return layers[0] if layers else None
    return layers
