"""Docs-freshness check: every subsystem must appear in the docs.

Walks ``src/repro/`` for subpackages (plus top-level modules like
``deploy.py``) and asserts each one is mentioned by name in BOTH
``README.md`` (the subsystem table) and ``docs/ARCHITECTURE.md`` (the
walkthroughs).  A new package added without a docs pass fails the lint
job; a package renamed or deleted leaves a stale mention behind, which
this check also flags.

Run from the repo root:

    python scripts/check_docs.py
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ("README.md", "docs/ARCHITECTURE.md")


def subsystems() -> list[str]:
    """Every documented unit under src/repro: subpackages + top-level
    modules (sans extension), e.g. ['checkpoint', ..., 'deploy']."""
    pkg = ROOT / "src" / "repro"
    names = []
    for p in sorted(pkg.iterdir()):
        if p.name.startswith(("_", ".")) or p.name == "__pycache__":
            continue
        if p.is_dir() and any(p.glob("*.py")):
            # some packages are namespace packages (no __init__.py)
            names.append(p.name)
        elif p.suffix == ".py":
            names.append(p.stem)
    return names


def mentioned(name: str, text: str) -> bool:
    # accept "repro/serve", "repro.serve", or "repro/deploy.py" forms
    return re.search(rf"repro[/.]{re.escape(name)}\b", text) is not None


def main() -> int:
    subs = subsystems()
    if not subs:
        print("check_docs: found no subsystems under src/repro — "
              "is the layout intact?")
        return 1
    failures = []
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            failures.append(f"{doc}: missing")
            continue
        text = path.read_text(encoding="utf-8")
        missing = [s for s in subs if not mentioned(s, text)]
        if missing:
            failures.append(f"{doc}: no mention of {', '.join(missing)}")
        # stale mentions: names referenced as repro/<x> that no longer exist
        referenced = set(re.findall(r"repro[/.](\w+)", text))
        stale = sorted(r for r in referenced if r not in set(subs))
        if stale:
            failures.append(f"{doc}: stale subsystem reference(s): "
                            f"{', '.join(stale)}")
    if failures:
        print("docs-freshness check FAILED:")
        for f in failures:
            print(f"  - {f}")
        print(f"subsystems under src/repro: {', '.join(subs)}")
        return 1
    print(f"docs-freshness OK: {len(subs)} subsystems covered in "
          f"{' and '.join(DOCS)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
