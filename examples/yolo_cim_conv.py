"""The paper's detection workload on the CiM conv kernels, end to end.

1. Compile Tiny-YOLO (DarkNet-style backbone + YOLO head) with
   `deploy.compile_model`: int8 trunks in ROM, 1/16-size trainable
   branches in SRAM.
2. Recompile the SAME network for each registered TrunkEngine
   (int8_native / dequant / pallas) and show the forwards agree.
3. Drop the CiM fidelity to the 5-bit-ADC per-subarray model and show the
   detection head barely moves (the paper's central claim).
4. Show the fused trunk+compress conv kernel against the unfused layer,
   and BN+leaky-ReLU folded into the engine's conv epilogue.

Run:  PYTHONPATH=src python examples/yolo_cim_conv.py
(CPU-friendly: 64x64 input; the real model runs 416x416.)
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro import deploy
from repro.core import cim, rebranch
from repro.kernels import ops
from repro.models import cnn

SIZE = 64
cfg = cnn.CNNConfig(name="tiny_yolo", input_size=SIZE)
model = deploy.compile_model(cfg)

key = jax.random.PRNGKey(0)
params = model.init(key)
x = jax.random.normal(jax.random.PRNGKey(1), (1, SIZE, SIZE, 3))

n_sram = rebranch.trainable_count(params)
n_rom = rebranch.frozen_count(params)
print(f"Tiny-YOLO @ {SIZE}px — ROM params: {n_rom:,}  "
      f"SRAM params: {n_sram:,}  ({n_rom / (n_rom + n_sram):.1%} in ROM)")

# -- 2. one forward per engine (same params, recompiled mapping) -------------
outs = {}
for impl in ("int8_native", "dequant", "pallas"):
    m = deploy.compile_model(cfg, engine=impl)
    outs[impl] = m.forward(params, x)
    print(f"engine={impl:12s} head: {outs[impl].shape} "
          f"finite: {bool(jnp.all(jnp.isfinite(outs[impl])))}")
for impl in ("dequant", "pallas"):
    d = float(jnp.max(jnp.abs(outs[impl] - outs["int8_native"])))
    s = float(jnp.std(outs["int8_native"]))
    print(f"  max |{impl} - int8_native| = {d:.4f}  (head std {s:.3f})")

# -- 3. 5-bit ADC fidelity ---------------------------------------------------
for mode in ("per_subarray", "bitserial"):
    m = deploy.compile_model(dataclasses.replace(
        cfg, rebranch=dataclasses.replace(cfg.rebranch,
                                          cim=cim.CiMConfig(mode=mode))))
    y = m.forward(params, x)
    rel = float(jnp.mean(jnp.abs(y - outs["int8_native"]))
                / (jnp.std(outs["int8_native"]) + 1e-9))
    print(f"CiM mode {mode:13s}: mean |err| = {rel:.4f} of head std "
          f"(5-bit ADC)")

# -- 4. fused trunk+compress kernel + fused BN/act epilogue ------------------
p0 = params["convs"][2]                     # a mid-backbone 3x3 conv
x0 = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 16, 32))
fused = ops.rebranch_conv(x0, p0["rom"]["w_q"], p0["rom"]["w_scale"],
                          p0["rom"]["C"], p0["sram"]["core"], p0["rom"]["U"])
unfused = cnn.apply_conv(p0, x0, cfg.rebranch)
print("\nfused rebranch_conv vs unfused layer max |err|:",
      float(jnp.max(jnp.abs(fused - unfused))))

y_fused_bn = cnn.apply_darknet(params, x,
                               dataclasses.replace(cfg, fuse_bn_act=True))
print("BN+leaky folded into conv epilogue vs unfused max |err|:",
      float(jnp.max(jnp.abs(y_fused_bn - outs["int8_native"]))))
