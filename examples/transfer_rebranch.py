"""The paper's core experiment (Figs. 10-11): ReBranch transfer learning.

Pretrain a VGG-8-style CNN on synthetic task A, tape it out into ROM
(int8, immutable), then transfer to task B by training ONLY the residual
branch (1/16 of the parameters).  Compares against the all-SRAM full
fine-tune upper bound and the frozen-trunk lower bound, and sweeps the
compression ratio D*U.

Run:  PYTHONPATH=src python examples/transfer_rebranch.py [--steps 220]
"""

import argparse

from benchmarks import transfer_harness as th


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=220)
    args = ap.parse_args()
    tc = th.TransferConfig(pretrain_steps=args.steps,
                           finetune_steps=args.steps)

    _, acc_a = th.pretrained_dense(tc)
    print(f"pretrained on task A: acc {acc_a:.3f}")

    acc_full, _ = th.run_transfer("full", tc)
    acc_frozen, _ = th.run_transfer("frozen", tc)
    print(f"task B  full fine-tune (all-SRAM): {acc_full:.3f}")
    print(f"task B  frozen trunk (no branch) : {acc_frozen:.3f}")

    print("\nReBranch D/U sweep (paper Fig. 11; D=U=4 is the paper's pick):")
    for d, u in [(2, 2), (4, 4), (8, 8)]:
        acc, frac = th.run_transfer("rebranch", tc, d_ratio=d, u_ratio=u)
        print(f"  D={d} U={u} (compression {d*u:2d}x, trainable "
              f"{frac:.3f}): acc {acc:.3f}  "
              f"(gap to full fine-tune {acc_full-acc:+.3f})")


if __name__ == "__main__":
    main()
