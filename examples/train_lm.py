"""End-to-end driver: train a ~100M-param LM with a frozen ROM trunk for a
few hundred steps on synthetic Markov data, with checkpoints + resume.

This wraps repro.launch.train with a ~100M reduced-but-real config (the
same code path the production launcher uses).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses

from repro import configs
from repro.launch import train as train_mod
from repro.models.config import ArchConfig


def lm_100m() -> ArchConfig:
    """~100M-param decoder (gemma-flavoured, GQA, GeGLU)."""
    return ArchConfig(
        name="lm_100m", family="dense",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=2,
        d_ff=2048, vocab_size=8192, mlp_type="geglu",
        dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    # register the config under a temp name so the driver can find it
    import repro.configs as cfgs
    import types, sys
    mod = types.ModuleType("repro.configs.lm_100m")
    mod.FULL = lm_100m()
    mod.SMOKE = lm_100m()
    sys.modules["repro.configs.lm_100m"] = mod

    losses = train_mod.main([
        "--arch", "lm_100m", "--smoke",
        "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--lr", "1e-3",
    ])
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{args.steps} steps")


if __name__ == "__main__":
    main()
