"""Quickstart: the YOLoC technique in five minutes.

1. Build a ReBranch linear layer (frozen int8 ROM trunk + trainable branch).
2. Show the CiM fidelity modes (ideal / per-subarray / bit-serial ADC).
3. Train ONLY the branch to adapt the frozen trunk to a new target.
4. Show the Pallas CiM kernel agreeing with the pure-jnp oracle.
5. Compile a whole model with `repro.deploy.compile_model`: pick a
   TrunkEngine from the registry and map ROM vs SRAM per layer.
6. Solve the ROM/SRAM placement from the cost model (`repro.plan`):
   the paper's Fig. 12 area map as a searchable artifact.
7. Kernel autotuning (`repro.tune`): the checked-in tuning table the
   kernels consult per GEMM geometry, and why only bit-identical
   tilings are legal entries.
8. Serving: continuous batching over one resident ROM cell
   (`repro.serve`).
9. Scenarios: N trained branches hot-swapped over ONE resident trunk
   (`repro.scenario`) — switching tasks is a branch swap, not a
   reload.
10. Paged KV: mixed prompt lengths through the paged block pool —
   the same plan-budgeted bytes admit more concurrent requests when
   short prompts stop paying full-horizon rows.
11. Speculative decode: the ReBranch branch IS the draft model —
   branch-only drafting (trunk skipped), one batched verify step
   through the full cell, rejected tails rolled back in the pool;
   accepted tokens bit-identical to plain greedy decode.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import deploy, engine, plan
from repro.configs.paper_models import PAPER_MODELS
from repro.core import cim, quant, rebranch, rom
from repro.kernels.cim_matmul import cim_matmul_pallas
from repro.kernels import ref
from repro.models import cnn

key = jax.random.PRNGKey(0)

# -- 1. a ReBranch layer ----------------------------------------------------
spec = rebranch.ReBranchSpec()          # D=U=4 -> branch is 1/16 of trunk
params = rebranch.init_linear(key, 256, 128, spec)
print(f"ROM bytes: {rom.rom_bytes(params):,}  "
      f"SRAM bytes: {rom.sram_bytes(params):,}  "
      f"fingerprint: {rom.rom_fingerprint(params)[:16]}...")

x = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
y = rebranch.apply_linear(params, x, spec)
print("forward:", y.shape, "finite:", bool(jnp.all(jnp.isfinite(y))))

# -- 2. CiM fidelity modes ---------------------------------------------------
x_q, sx = quant.quantize_activations(x)
w_q = params["rom"]["w_q"]
exact = cim.cim_matmul_model(x_q, w_q, cim.CiMConfig(mode="ideal"))
for mode in ("per_subarray", "bitserial"):
    out = cim.cim_matmul_model(x_q, w_q, cim.CiMConfig(mode=mode))
    err = float(jnp.mean(jnp.abs(out - exact)) / (jnp.std(exact) + 1e-9))
    print(f"CiM mode {mode:13s}: mean |err| = {err:.4f} of output std "
          f"(5-bit ADC)")

# -- 3. branch-only adaptation ------------------------------------------------
# a weight shift in the branch's representable family C*R*U (the paper's
# premise: transfer residuals are low-energy and absorbable by the branch;
# a generic full-rank shift would need full fine-tuning)
r = jax.random.normal(jax.random.PRNGKey(2),
                      params["sram"]["core"].shape) * 0.3
target_w = (params["rom"]["C"] @ r @ params["rom"]["U"]).astype(jnp.float32)
trainable, frozen = rebranch.partition(params)

def loss_fn(t):
    p = rebranch.combine(t, frozen)
    pred = rebranch.apply_linear(p, x, spec)
    return jnp.mean((pred - (y + x @ target_w)) ** 2)   # shifted target

print("\nadapting the branch to a shifted target (trunk frozen):")
lr = 0.5
for i in range(201):
    l, g = jax.value_and_grad(loss_fn)(trainable)
    trainable = jax.tree.map(
        lambda p, gg: p if gg is None else p - lr * gg, trainable, g,
        is_leaf=lambda v: v is None)
    if i % 50 == 0:
        print(f"  step {i:3d}: loss {float(l):.6f}")

fp_before = rom.rom_fingerprint(params)
fp_after = rom.rom_fingerprint(rebranch.combine(trainable, frozen))
print("ROM untouched by training:", fp_before == fp_after)

# -- 4. Pallas kernel vs oracle ----------------------------------------------
cfg = cim.CiMConfig(mode="bitserial")
got = cim_matmul_pallas(x_q, w_q, cfg, interpret=True)
want = ref.cim_matmul_ref(x_q, w_q, cfg)
print("\nPallas CiM kernel vs oracle max |err|:",
      float(jnp.max(jnp.abs(got - want))))

# -- 5. compile a model: engine registry + per-layer ROM/SRAM mapping ---------
# every frozen trunk dispatches through a named TrunkEngine; resolution is
# strict (typos raise with the registered set) and new backends plug in
# with engine.register(...) — no model code changes.
print("\nregistered engines:", engine.registered_names())

model = deploy.compile_model(
    cnn.CNNConfig(name="vgg8", input_size=32),
    engine="int8_native",
    layer_overrides={
        "convs.0": {"memory": "sram"},      # first conv stays trainable
        "convs.5": {"engine": "dequant"},   # last conv on the float baseline
    })
print("compiled:", model)
p_cnn = model.init(jax.random.PRNGKey(0))
img = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
print("vgg8 logits:", model.forward(p_cnn, img).shape,
      "| conv0 in SRAM:", "rom" not in p_cnn["convs"][0],
      "| conv5 engine:", model.layer_spec("convs.5").trunk_impl)

# -- 6. cost-driven placement: the Fig. 12 area map from the solver -----------
# Instead of hand-writing which layers stay SRAM-trainable, price every
# site with the Table-I cost model and solve under an area budget: small
# early/late layers flip to SRAM first, the bulk mid convs stay ROM —
# the paper's Fig. 12 shape, now produced by `plan.solve`.
dn = PAPER_MODELS["darknet19"]
design = plan.solve(dn)                  # all-ROM+branch design point
stats = design.stats(dn)
print(f"\ndarknet19 design point: {stats.rom_bits / 1e6:.0f} Mbit ROM + "
      f"{stats.branch_bits / 1e6:.0f} Mbit SRAM branch = "
      f"{plan.plan_area_mm2(stats):.0f} mm2, "
      f"{plan.efficiency_vs_iso_sram(stats, reload_factor=3.0):.1f}x "
      f"energy vs iso-area SRAM-CiM")
budget = plan.plan_area_mm2(stats) * 2.5      # grant 2.5x the min area
solved = plan.solve(dn, budget)
resid = {s: "S" if not sp.enabled else "R" for s, sp in solved.entries}
tree = plan.site_tree(dn)
print(f"at {budget:.0f} mm2 the solver maps (R=ROM trunk, S=SRAM):")
print("  " + " ".join(f"{s.name.split('.')[-1]}:{resid.get(s.name, 'R')}"
                      for s in tree))
# deploy it — bit-identical to the equivalent hand-written overrides
model = deploy.compile_model(dn, plan=solved)
print("deployed:", model)

# -- 7. kernel autotuning: the tuning table behind the Pallas kernels ---------
# Every Pallas kernel call with unspecified block sizes consults the
# checked-in per-geometry table (regenerate: python -m repro.tune).  A
# table entry may change how FAST a kernel runs, never WHAT it returns:
# the k-partition fixes the per-block activation quant scales, so only
# block_k values reproducing the default partition are legal — block_m /
# block_n / grid dim order / grid-vs-direct impl are the free axes.
from repro.kernels.rebranch_conv import trunk_conv_pallas
from repro.tune import autotune, table

m, kdim, n = 16 * 16, 3 * 3 * 32, 64          # a DarkNet-19 conv site's
print("\npatch GEMM", (m, kdim, n),           # implied patch GEMM
      "-> table:", table.lookup("trunk_conv", "ideal", "float32",
                                m, kdim, n))
print("legal block_k at k=576:", autotune.legal_block_ks(576),
      "(128/256 would re-partition the contraction = different bits)")

xc = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16, 32))
wc = jax.random.randint(jax.random.PRNGKey(4), (3, 3, 32, 64),
                        -127, 128, jnp.int8)
ws = jnp.full((64,), 0.01, jnp.float32)
tuned = trunk_conv_pallas(xc, wc, ws)         # table-resolved tiling
with table.disabled():                        # force kernel defaults
    untuned = trunk_conv_pallas(xc, wc, ws)
print("tuned output bit-identical to untuned:",
      bool(np.array_equal(np.asarray(tuned), np.asarray(untuned))),
      "| deploy.compile_model(..., tune=True) asserts the engine "
      "has tuned kernels")

# -- 8. serving: continuous batching over one resident ROM cell ---------------
# ROM weights never move, so one compiled cell amortizes across as many
# concurrent users as the scheduler can feed it.  serve.load() is the
# front door: the registry maps a model id to (config, plan, engine,
# tune), compiles it ONCE per process, and sizes the slot-based KV pool
# from the plan's SRAM residency stats.  Requests join the batch at
# decode-step boundaries (solo bit-identical prefill -> adopted cache
# row) and retire without draining the batch.
import asyncio
from repro import serve

srv = serve.load("gemma-2b-smoke", max_len=48)   # LMServer over the pool
print(f"\nserving gemma-2b-smoke with a {srv.pool.n_slots}-slot KV pool")
rng = np.random.default_rng(0)
prompts = [rng.integers(0, 512, size=8 + i) for i in range(4)]

async def users():
    # four concurrent users: the cooperative pump decodes them as ONE
    # batch — same tokens as four solo prefill+decode runs, bit for bit
    return await asyncio.gather(
        *[srv.generate(p, max_new_tokens=6) for p in prompts])

streams = asyncio.run(users())
print("per-user streams:", [s[:3] for s in streams])
done = srv.batcher.step_count
print(f"4 users x 6 tokens in {done} decode steps "
      f"(solo would take {4 * 6}) — one ROM cell, "
      f"{len(prompts)} rows in flight")
# the same front door serves CNN configs forward-only:
cnn_srv = serve.load("vgg8-32", n_slots=4)
img = rng.normal(size=(1, 32, 32, 3)).astype(np.float32)
print("vgg8 via serve front door:", cnn_srv.submit(img).shape,
      "| latency report: python -m benchmarks.serve_load --fast")

# -- 9. scenarios: many branches, one trunk -----------------------------------
# The ROM trunk is immutable, but the SRAM branch is tiny — so a
# "scenario" (a dataset, a task, a deployment condition) is just a
# trained branch tree.  repro.scenario extracts branches as tagged
# bundles (model + placement-plan fingerprint: a branch can never
# implant onto a mismatched placement), the ScenarioStore LRU-caches
# them on device, and the serving layer swaps them over the resident
# trunk with ONE donated combine — no recompile, zero ROM traffic.
from repro import scenario

cfg9 = cnn.CNNConfig(name="vgg8", input_size=32)
plan9 = plan.PlacementPlan.from_config(cfg9)
model9 = deploy.compile_model(cfg9, plan=plan9)
p_day = model9.init(jax.random.PRNGKey(0))
# stand-ins for two trained scenarios (see benchmarks/scenario_swap.py
# for the real flow: K branches trained on one trunk via the Fig. 10
# transfer harness)
br_day, trunk = scenario.split_params(p_day)
br_night = jax.tree.map(lambda v: v + 0.01, br_day)

serve.register(serve.ModelEntry("vgg8-demo", config=lambda: cfg9,
                                plan=lambda c: plan9), override=True)
store = serve.scenario_store("vgg8-demo")
store.register("day", branch=br_day)
store.register("night", branch=br_night)
# load() swaps with a DONATED combine — hand it its own copy so the
# br_night/trunk views split above stay valid for the parity check
srv9 = serve.load("vgg8-demo", params=jax.tree.map(jnp.array, p_day),
                  n_slots=2, scenario="day")
img2 = np.concatenate([img, img])            # one full 2-slot chunk
out_day = srv9.submit(img2)
srv9.swap_scenario("night")                  # one donated combine
out_night = srv9.submit(img2)
fresh = jax.jit(model9.forward)(rebranch.combine(br_night, trunk),
                                jnp.asarray(img2))
print(f"\nscenario swap day->night on one resident trunk: outputs "
      f"differ: {not np.array_equal(out_day, out_night)} | night "
      f"bit-identical to a fresh cell: "
      f"{np.array_equal(out_night, np.asarray(fresh))}")
print("swap vs full reload latency: "
      "python -m benchmarks.scenario_swap --fast")

# -- 10. paged KV: mixed prompt lengths, one block pool -----------------------
# A dense slot pool charges every request a full-horizon cache row, so
# a 6-token prompt pays the same SRAM as a 40-token one.  The PagedPool
# carves the SAME byte budget into fixed-size blocks shared through
# per-request block tables: blocks are reserved at admission (so decode
# can never deadlock) but granted on demand, and the attention gathers
# the logical row through the table — bit-identical to the dense path.
model10, _ = serve.compile_entry("gemma-2b-smoke")
p10 = model10.init(jax.random.PRNGKey(0))
lens = [6, 38, 10, 30, 8, 22]                # the mixed-length load
load10 = [rng.integers(0, 512, size=n) for n in lens]

def race(paged):
    # paged: same bytes as the 3 dense rows (3 * 48/8 blocks), 6 rows
    s = serve.LMServer(model10, p10, n_slots=6 if paged else 3,
                       max_len=48, paged=paged, block_size=8,
                       n_blocks=18, prefill_chunk=16)
    reqs = [s.submit(p, 4) for p in load10]
    peak, util = 0, []
    while not s.batcher.idle:
        s.step()
        peak = max(peak, s.batcher.active)
        live = sum(r.prompt.size + len(r.tokens)
                   for r in s.batcher._active.values())
        held = (s.pool.blocks_in_use * s.pool.block_size if paged
                else s.pool.occupancy * s.pool.max_len)
        if held:
            util.append(live / held)
    return ([list(r.tokens) for r in reqs], peak,
            float(np.mean(util)), s.batcher.step_count)

dense_toks, dense_peak, dense_util, _ = race(paged=False)
paged_toks, paged_peak, paged_util, steps10 = race(paged=True)
print(f"\npaged KV over one block pool: same bytes, "
      f"{paged_peak} rows in flight vs {dense_peak} dense | "
      f"pool utilization {paged_util:.2f} vs {dense_util:.2f} "
      f"(fragmentation {1 - paged_util:.2f} vs {1 - dense_util:.2f})")
print("paged tokens bit-identical to dense pool:",
      paged_toks == dense_toks,
      "| mixed-length race: python -m benchmarks.serve_load --fast")

# -- 11. speculative decode: the branch drafts, the trunk verifies ------------
# The ReBranch branch is a free draft model: api.draft_config flips
# trunk_skip=True on every ReBranch site, so the draft forward runs only
# the SRAM-resident branch — (x@C)@(core@U) — over the SAME params tree
# (control flow, not weights).  Each round the batcher drafts spec_k
# tokens through the branch-only cell, then verifies the whole block in
# ONE decode-width-k dispatch through the full trunk+branch cell; the
# longest matching prefix (plus the verify argmax at the first mismatch)
# is accepted, and the pool rolls back the rejected tail — lengths
# truncate, paged blocks return to the free list.  Greedy output is
# bit-identical to non-speculative decode, whatever the drafter does.
def decode_all(spec_k):
    s = serve.LMServer(model10, p10, n_slots=3, max_len=48, paged=True,
                      block_size=8, n_blocks=18, spec_k=spec_k)
    reqs = [s.submit(p, 6) for p in load10[:3]]
    while not s.batcher.idle:
        s.step()
    assert s.pool.blocks_in_use == 0 and s.pool.blocks_reserved == 0
    return [list(r.tokens) for r in reqs], s.batcher

plain_toks, _ = decode_all(spec_k=0)
spec_toks, b11 = decode_all(spec_k=3)
print(f"\nspeculative decode (spec_k=3, branch drafts): "
      f"{b11.spec_rounds} verify rounds for "
      f"{sum(len(t) for t in spec_toks)} tokens, "
      f"acceptance {b11.acceptance_rate:.2f}, no leaked blocks")
print("spec tokens bit-identical to plain greedy decode:",
      spec_toks == plain_toks,
      "| speed race: python -m benchmarks.spec_decode --fast")
