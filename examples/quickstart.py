"""Quickstart: the YOLoC technique in five minutes.

1. Build a ReBranch linear layer (frozen int8 ROM trunk + trainable branch).
2. Show the CiM fidelity modes (ideal / per-subarray / bit-serial ADC).
3. Train ONLY the branch to adapt the frozen trunk to a new target.
4. Show the Pallas CiM kernel agreeing with the pure-jnp oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim, quant, rebranch, rom
from repro.kernels.cim_matmul import cim_matmul_pallas
from repro.kernels import ref

key = jax.random.PRNGKey(0)

# -- 1. a ReBranch layer ----------------------------------------------------
spec = rebranch.ReBranchSpec()          # D=U=4 -> branch is 1/16 of trunk
params = rebranch.init_linear(key, 256, 128, spec)
print(f"ROM bytes: {rom.rom_bytes(params):,}  "
      f"SRAM bytes: {rom.sram_bytes(params):,}  "
      f"fingerprint: {rom.rom_fingerprint(params)[:16]}...")

x = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
y = rebranch.apply_linear(params, x, spec)
print("forward:", y.shape, "finite:", bool(jnp.all(jnp.isfinite(y))))

# -- 2. CiM fidelity modes ---------------------------------------------------
x_q, sx = quant.quantize_activations(x)
w_q = params["rom"]["w_q"]
exact = cim.cim_matmul_model(x_q, w_q, cim.CiMConfig(mode="ideal"))
for mode in ("per_subarray", "bitserial"):
    out = cim.cim_matmul_model(x_q, w_q, cim.CiMConfig(mode=mode))
    err = float(jnp.mean(jnp.abs(out - exact)) / (jnp.std(exact) + 1e-9))
    print(f"CiM mode {mode:13s}: mean |err| = {err:.4f} of output std "
          f"(5-bit ADC)")

# -- 3. branch-only adaptation ------------------------------------------------
# a weight shift in the branch's representable family C*R*U (the paper's
# premise: transfer residuals are low-energy and absorbable by the branch;
# a generic full-rank shift would need full fine-tuning)
r = jax.random.normal(jax.random.PRNGKey(2),
                      params["sram"]["core"].shape) * 0.3
target_w = (params["rom"]["C"] @ r @ params["rom"]["U"]).astype(jnp.float32)
trainable, frozen = rebranch.partition(params)

def loss_fn(t):
    p = rebranch.combine(t, frozen)
    pred = rebranch.apply_linear(p, x, spec)
    return jnp.mean((pred - (y + x @ target_w)) ** 2)   # shifted target

print("\nadapting the branch to a shifted target (trunk frozen):")
lr = 0.5
for i in range(201):
    l, g = jax.value_and_grad(loss_fn)(trainable)
    trainable = jax.tree.map(
        lambda p, gg: p if gg is None else p - lr * gg, trainable, g,
        is_leaf=lambda v: v is None)
    if i % 50 == 0:
        print(f"  step {i:3d}: loss {float(l):.6f}")

fp_before = rom.rom_fingerprint(params)
fp_after = rom.rom_fingerprint(rebranch.combine(trainable, frozen))
print("ROM untouched by training:", fp_before == fp_after)

# -- 4. Pallas kernel vs oracle ----------------------------------------------
cfg = cim.CiMConfig(mode="bitserial")
got = cim_matmul_pallas(x_q, w_q, cfg, interpret=True)
want = ref.cim_matmul_ref(x_q, w_q, cfg)
print("\nPallas CiM kernel vs oracle max |err|:",
      float(jnp.max(jnp.abs(got - want))))
