"""Serving example: batched prefill + autoregressive decode with the KV
cache, on a ReBranch (frozen-trunk) model — the serve_step the multi-pod
dry-run lowers, executed for real on a small config.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp

from repro import configs, deploy
from repro.launch import steps as steps_lib

ARCH = "gemma_2b"
BATCH, PROMPT, GEN = 4, 32, 16


def main():
    cfg = configs.get_smoke(ARCH)
    model = deploy.compile_model(cfg)   # one compile, whole serve surface
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    prompt = jax.random.randint(key, (BATCH, PROMPT), 0, cfg.vocab_size)
    cache = model.init_cache(BATCH, PROMPT + GEN, dtype=jnp.float32)

    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, {"tokens": prompt}, cache)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    print(f"prefill {BATCH}x{PROMPT}: {(time.time()-t0)*1e3:.0f} ms")

    serve_step = jax.jit(steps_lib.make_serve_step(cfg, model=model))
    out = [tok]
    t0 = time.time()
    for _ in range(GEN - 1):
        tok, cache = serve_step(params, {"tokens": tok}, cache)
        out.append(tok)
    dt = (time.time() - t0) / (GEN - 1)
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {GEN} tokens/seq @ {dt*1e3:.1f} ms/step")
    print("sample stream:", gen[0].tolist())
    assert gen.shape == (BATCH, GEN)
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab_size)))
    print("OK")


if __name__ == "__main__":
    main()
