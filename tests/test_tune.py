"""Kernel autotuner + tuning table (repro.tune).

The load-bearing contract: a tuning-table entry may change how fast a
kernel runs, NEVER what it returns.  Covers:

  * table mechanics — round-trip determinism, lookup fallback on unseen
    keys, overrides()/disabled() context stack;
  * legality — legal_block_ks only emits block_k values reproducing the
    default k-partition, candidates() orders direct-first, and the
    resolve_tiling k-partition guard drops hand-edited illegal entries;
  * bit parity — the checked-in table resolves bit-identically to the
    untuned defaults through the real kernels in all three fidelity
    modes, grid dim-order / block-shape candidates are bit-identical to
    each other, and explicit block_k clamping is value-neutral;
  * dispatch — the pallas_fused engine routes live-branch sites through
    the fused kernels, and deploy.compile_model's tune= gate.
"""

import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import deploy, engine
from repro.core import cim as cim_lib
from repro.core.rebranch import ReBranchSpec
from repro.kernels.cim_matmul import cim_matmul_pallas
from repro.kernels.tiling import k_partition, resolve_tiling
from repro.models import cnn
from repro.tune import autotune, table

# the package re-exports jitted ops shadowing the submodule name
_rc = importlib.import_module("repro.kernels.rebranch_conv")

MODES = ["ideal", "per_subarray", "bitserial"]


def _conv_inputs(key, kk, c_in, c_out, hw):
    x = jax.random.normal(key, (1, hw, hw, c_in), jnp.float32)
    w_q = jax.random.randint(jax.random.fold_in(key, 1),
                             (kk, kk, c_in, c_out), -127, 128, jnp.int8)
    w_scale = jnp.full((c_out,), 0.01, jnp.float32)
    return x, w_q, w_scale


# ---------------------------------------------------------------------------
# table mechanics
# ---------------------------------------------------------------------------

class TestTable:
    def test_round_trip_and_determinism(self, tmp_path):
        entries = {
            table.key("trunk_conv", "ideal", "float32", 64, 576, 128):
                table.Tiling(128, 128, 512, "kmn", "direct"),
            table.key("cim_matmul", "bitserial", "int8", 16, 288, 32):
                table.Tiling(64, 64, 384, "mnk", "grid"),
        }
        p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
        table.save_table(entries, str(p1), meta={"models": ["x"]})
        table.save_table(dict(reversed(list(entries.items()))), str(p2),
                         meta={"models": ["x"]})
        # insertion order must not leak into the bytes (CI diffs on this)
        assert p1.read_bytes() == p2.read_bytes()
        loaded = {k: table.Tiling.from_json(v)
                  for k, v in json.loads(p1.read_text())["entries"].items()}
        assert loaded == entries

    def test_lookup_unseen_key_is_none(self):
        assert table.lookup("trunk_conv", "ideal", "float32",
                            7, 7919, 13) is None

    def test_overrides_and_disabled_stack(self):
        k = table.key("trunk_conv", "ideal", "float32", 8, 256, 8)
        t = table.Tiling(64, 64, 256, "mnk", "direct")
        with table.overrides({k: t}):
            assert table.lookup("trunk_conv", "ideal", "float32",
                                8, 256, 8) == t
            with table.disabled():
                assert table.lookup("trunk_conv", "ideal", "float32",
                                    8, 256, 8) is None
            assert table.lookup("trunk_conv", "ideal", "float32",
                                8, 256, 8) == t

    def test_tiling_validation(self):
        with pytest.raises(ValueError):
            table.Tiling(128, 128, 512, dim_order="nkm")
        with pytest.raises(ValueError):
            table.Tiling(128, 128, 512, impl="magic")

    def test_checked_in_table_is_consistent(self):
        # the CI smoke step (python -m repro.tune --check) as a test
        assert autotune.check_table(log=lambda *a, **k: None)

    def test_batched_geometries_scale_the_m_axis(self):
        """Serving batch sizes enumerate DISTINCT table keys: the patch
        GEMM's M axis is batch*OH*OW, so a micro-batched CNNServer
        dispatch must not fall back to untuned defaults."""
        solo = autotune.conv_geometries(
            ("tiny_yolo",), (32,), ("ideal",), ("trunk_conv",))
        both = autotune.conv_geometries(
            ("tiny_yolo",), (32,), ("ideal",), ("trunk_conv",),
            batches=(1, 8))
        solo_keys = {g.key for g in solo}
        assert solo_keys < {g.key for g in both}       # strict superset
        by_shape = {(g.m, g.k, g.n): g for g in both}
        for g in solo:
            batched = by_shape.get((8 * g.m, g.k, g.n))
            assert batched is not None, f"no batch-8 twin for {g.key}"
            assert batched.conv[5] == 8 and g.conv[5] == 1
        # meta round-trip: a table generated with batches checks clean
        # against the same enumeration (and a legacy table without the
        # key falls back to solo-only)
        assert autotune.conv_geometries(
            ("tiny_yolo",), (32,), ("ideal",), ("trunk_conv",),
            batches=(1,)) == solo


# ---------------------------------------------------------------------------
# legality
# ---------------------------------------------------------------------------

class TestLegality:
    @pytest.mark.parametrize("k,expect", [
        (288, [384]),     # round_up(288,128)=384: 128/256 split it, 512 dups
        (576, [512]),     # two-block partition — only the default survives
        (64, [128]),      # sub-subarray contraction clamps everything to 128
    ])
    def test_legal_block_ks(self, k, expect):
        assert autotune.legal_block_ks(k) == expect
        base = k_partition(k, 512, 128)
        for bk in autotune.legal_block_ks(k):
            assert k_partition(k, bk, 128) == base

    def test_candidates_direct_first_and_legal(self):
        cands = autotune.candidates("trunk_conv", 64, 576, 128, fast=True)
        assert cands[0].impl == "direct"
        base = k_partition(576, 512, 128)
        for c in cands:
            assert k_partition(576, c.block_k, 128) == base
        # fast sweep: impl/dim-order only, no block_m/n fan-out
        assert {(c.block_m, c.block_n) for c in cands
                if c.impl == "grid"} == {(128, 128)}

    def test_resolve_tiling_explicit_beats_table(self):
        k = table.key("trunk_conv", "ideal", "float32", 64, 576, 128)
        with table.overrides({k: table.Tiling(256, 256, 512,
                                              "kmn", "direct")}):
            t = resolve_tiling("trunk_conv", "ideal", "float32", 64, 576,
                               128, block_m=32, block_n=None, block_k=None,
                               defaults=(128, 128, 512), rows=128)
        # any explicit block size disables the lookup entirely
        assert (t.block_m, t.block_n, t.block_k) == (32, 128, 512)
        assert t.dim_order == "mnk"

    def test_resolve_tiling_drops_illegal_block_k(self):
        # a hand-edited entry that would split the 576-contraction into
        # 128-blocks — different per-block quant scales, different bits
        k = table.key("trunk_conv", "ideal", "float32", 64, 576, 128)
        with table.overrides({k: table.Tiling(128, 128, 128,
                                              "mnk", "direct")}):
            t = resolve_tiling("trunk_conv", "ideal", "float32", 64, 576,
                               128, block_m=None, block_n=None, block_k=None,
                               defaults=(128, 128, 512), rows=128)
        assert t.block_k == 512


# ---------------------------------------------------------------------------
# bit parity through the real kernels
# ---------------------------------------------------------------------------

class TestBitParity:
    @pytest.mark.parametrize("mode", MODES)
    def test_checked_in_table_is_bit_neutral(self, mode):
        """Shipping-table resolution == untuned defaults, exactly.

        Two geometries: gk=1 (288-wide patch rows) and gk=2 (576-wide,
        ragged 64-column tail) — the regimes the direct lowering
        dispatches differently.
        """
        cfg = cim_lib.CiMConfig(mode=mode)
        for kk, c_in, c_out, hw in [(3, 32, 32, 8), (3, 64, 32, 4)]:
            x, w_q, w_scale = _conv_inputs(
                jax.random.PRNGKey(hw), kk, c_in, c_out, hw)
            with table.disabled():
                ref = np.asarray(_rc.trunk_conv_pallas(x, w_q, w_scale, cfg))
            out = np.asarray(_rc.trunk_conv_pallas(x, w_q, w_scale, cfg))
            assert np.array_equal(ref, out), (mode, c_in)

    @pytest.mark.parametrize("mode", MODES)
    def test_fused_conv_table_bit_neutral(self, mode):
        cfg = cim_lib.CiMConfig(mode=mode)
        key = jax.random.PRNGKey(3)
        p = cnn.init_conv(key, 3, 64, 32, ReBranchSpec())
        x = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 4, 64))
        rom, sram = p["rom"], p["sram"]
        args = (x, rom["w_q"], rom["w_scale"], rom["C"], sram["core"],
                rom["U"])
        with table.disabled():
            ref = np.asarray(_rc.rebranch_conv_pallas(*args, cfg))
        assert np.array_equal(ref, np.asarray(
            _rc.rebranch_conv_pallas(*args, cfg))), mode

    def test_grid_candidates_bit_identical_to_each_other(self):
        """dim_order / block-shape moves never touch the grid's bits.

        (grid-vs-DIRECT is tolerance-equal only — different f32
        intermediates — which is why the autotuner verifies candidates
        empirically against the default path and drops mismatches
        instead of tabulating them.)
        """
        cfg = cim_lib.CiMConfig(mode="ideal")
        x, w_q, w_scale = _conv_inputs(jax.random.PRNGKey(7), 3, 64, 32, 4)
        geo_key = table.key("trunk_conv", "ideal", "float32",
                            16, 576, 32)
        outs = []
        for cand in autotune.candidates("trunk_conv", 16, 576, 32,
                                        fast=True):
            if cand.impl != "grid":
                continue
            with table.overrides({geo_key: cand}):
                outs.append(np.asarray(_rc.trunk_conv_pallas(
                    x, w_q, w_scale, cfg, interpret=True)))
        assert len(outs) >= 2           # both dim orders raced
        for o in outs[1:]:
            assert np.array_equal(outs[0], o)

    def test_cim_matmul_block_k_clamp_value_neutral(self):
        # k=64 < rows_per_subarray: every block_k clamps to one
        # 128-padded block, so explicit sizes can't change the result
        cfg = cim_lib.CiMConfig(mode="per_subarray")
        key = jax.random.PRNGKey(11)
        x_q = jax.random.randint(key, (32, 64), -127, 128, jnp.int8)
        w_q = jax.random.randint(jax.random.fold_in(key, 1),
                                 (64, 48), -127, 128, jnp.int8)
        a = np.asarray(cim_matmul_pallas(x_q, w_q, cfg, block_k=512))
        b = np.asarray(cim_matmul_pallas(x_q, w_q, cfg, block_k=128))
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# dispatch: fused engine + deploy gate
# ---------------------------------------------------------------------------

class TestDispatch:
    def test_pallas_fused_capabilities(self):
        eng = engine.get("pallas_fused")
        assert eng.capabilities.tune
        assert set(eng.capabilities.fused_ops) == {"conv", "matmul"}
        assert not eng.capabilities.grads       # inference-only fast path

    def test_fused_engine_matches_unfused_pallas(self):
        key = jax.random.PRNGKey(5)
        p = cnn.init_conv(key, 3, 32, 32, ReBranchSpec())
        x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 8, 32))
        y_ref = cnn.apply_conv(p, x, ReBranchSpec(trunk_impl="pallas"))
        y_fused = cnn.apply_conv(p, x, ReBranchSpec(trunk_impl="pallas_fused"))
        # identical trunk bits; the branch legs associate differently
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_fused),
                                   rtol=2e-4, atol=2e-4)

    def test_compile_model_tune_gate(self):
        cfg = cnn.CNNConfig(name="vgg8", num_classes=13, input_size=16)
        with pytest.raises(ValueError, match="tune=True"):
            deploy.compile_model(cfg, engine="dequant", tune=True)
        # table-aware engines pass the gate; tune=False binds the
        # baseline (table-disabled) policy without complaint
        assert deploy.compile_model(cfg, engine="pallas",
                                    tune=True).tune is True
        assert deploy.compile_model(cfg, engine="dequant",
                                    tune=False).tune is False
