"""Halo-exchange sharded conv: parity with the unsharded Pallas engine.

The 'pallas_sharded' contract is BIT-identity: per-device results equal
the single-device 'pallas' engine exactly (same per-row quantisation,
same k-block accumulation order — see kernels/halo_conv.py).  Multi-
device cases run in subprocesses with forced host devices (kept OUT of
this process so other tests see 1 device, per the dry-run rule); the
halo *plan* math and the no-mesh fallback are tested in-process.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# halo plan math (pure, no devices)
# ---------------------------------------------------------------------------

class TestHaloPlan:
    def test_aligned_stride1(self):
        from repro.kernels.halo_conv import plan_halo
        p = plan_halo(16, 3, 1, "SAME", 4)
        assert p.aligned and (p.top, p.bot) == (1, 1)
        assert (p.pad_top, p.pad_bot) == (0, 0)
        assert (p.oh, p.ol) == (16, 4)

    def test_aligned_stride2_pads_bottom_only(self):
        from repro.kernels.halo_conv import plan_halo
        # SAME s=2 k=3 on even H: ph0=0, all halo flows upward
        p = plan_halo(16, 3, 2, "SAME", 4)
        assert p.aligned and (p.top, p.bot) == (0, 1)
        assert (p.oh, p.ol) == (8, 2)

    def test_no_halo_1x1(self):
        from repro.kernels.halo_conv import plan_halo
        p = plan_halo(16, 1, 1, "SAME", 4)
        assert p.aligned and (p.top, p.bot) == (0, 0)

    def test_uneven_h_general_path(self):
        from repro.kernels.halo_conv import plan_halo
        p = plan_halo(9, 3, 2, "SAME", 4)     # oh=5, ph0=1
        assert not p.aligned
        assert p.pad_top == 1                  # materialised global top pad
        assert p.n * p.ol >= p.oh              # all outputs covered
        # materialised rows cover every real input row
        assert p.pad_top + 9 + p.pad_bot == p.n * p.ol * 2

    def test_infeasible_returns_none(self):
        from repro.kernels.halo_conv import plan_halo
        # 5x5 kernel, 1-row shards: halo spans >1 neighbour -> None
        assert plan_halo(4, 5, 1, "SAME", 4) is None

    def test_halo_bytes(self):
        from repro.kernels.halo_conv import halo_bytes
        # 3x3 stride-1: 2 halo rows x N2 x W8 x C20 x 4B
        assert halo_bytes((2, 16, 8, 20), 3, 1, "SAME", 4) == 2 * 2 * 8 * 20 * 4
        assert halo_bytes((2, 16, 8, 20), 1, 1, "SAME", 4) == 0


# ---------------------------------------------------------------------------
# no-mesh fallback (in-process, 1 device)
# ---------------------------------------------------------------------------

class TestFallback:
    def test_registered_with_honest_capabilities(self):
        from repro import engine
        eng = engine.get("pallas_sharded")
        assert eng.capabilities.sharded_ops == ("conv",)
        assert eng.capabilities.epilogue

    def test_no_mesh_falls_back_to_pallas(self):
        import jax
        import numpy as np
        from repro import engine
        from repro.core import cim as cim_lib
        from repro.core import rebranch
        from repro.models import cnn

        cfg = cim_lib.CiMConfig(mode="ideal")
        p = cnn.init_conv(jax.random.PRNGKey(0), 3, 20, 12,
                          rebranch.ReBranchSpec())
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 20))
        w_q, w_scale = p["rom"]["w_q"], p["rom"]["w_scale"]
        got = engine.get("pallas_sharded").conv(cfg, x, w_q, w_scale)
        want = engine.get("pallas").conv(cfg, x, w_q, w_scale)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_no_mesh_fallback_is_silent(self):
        """Running without a mesh is normal single-device operation, not a
        surprise — no warning."""
        import warnings

        import jax
        from repro import engine
        from repro.core import cim as cim_lib
        from repro.core import rebranch
        from repro.models import cnn

        p = cnn.init_conv(jax.random.PRNGKey(0), 3, 8, 8,
                          rebranch.ReBranchSpec())
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 8))
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            engine.get("pallas_sharded").conv(
                cim_lib.CiMConfig(mode="ideal"), x,
                p["rom"]["w_q"], p["rom"]["w_scale"])


def test_halo_doesnt_fit_fallback_warns_once():
    """When a mesh IS bound but the halo would span more than one
    neighbour shard, the engine must say so (once per geometry) instead
    of silently dropping the sharding the deployment asked for."""
    out = _run(textwrap.dedent("""
        import warnings
        import jax, jax.numpy as jnp
        from repro import engine as engine_lib
        from repro.core import cim as cim_lib
        from repro.core import rebranch
        from repro.distributed import sharding as shd
        from repro.models import cnn

        cfg = cim_lib.CiMConfig(mode="ideal")
        p = cnn.init_conv(jax.random.PRNGKey(0), 5, 8, 8,
                          rebranch.ReBranchSpec())
        # H=8 over 8 shards -> 1 row/shard < the 5x5 kernel's 2-row halo:
        # infeasible, the engine must fall back unsharded (and say so)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 8))
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        eng = engine_lib.get("pallas_sharded")
        with shd.use_mesh(mesh), mesh:
            with warnings.catch_warnings(record=True) as w1:
                warnings.simplefilter("always")
                y = eng.conv(cfg, x, p["rom"]["w_q"], p["rom"]["w_scale"])
            with warnings.catch_warnings(record=True) as w2:
                warnings.simplefilter("always")
                y = eng.conv(cfg, x, p["rom"]["w_q"], p["rom"]["w_scale"])
        hits1 = [m for m in w1 if "falling back" in str(m.message)]
        hits2 = [m for m in w2 if "falling back" in str(m.message)]
        print("WARNED_FIRST", len(hits1))
        print("WARNED_AGAIN", len(hits2))
        print("MSG_OK", "halo for H=8 kh=5" in str(hits1[0].message)
              if hits1 else False)
    """))
    assert "WARNED_FIRST 1" in out, out
    assert "WARNED_AGAIN 0" in out, out          # one-time per geometry
    assert "MSG_OK True" in out, out


# ---------------------------------------------------------------------------
# multi-device bit-parity (subprocess, forced host devices)
# ---------------------------------------------------------------------------

def test_sharded_trunk_conv_bit_identical_sweep():
    """pallas_sharded == pallas bit-for-bit over 1/2/4-way H-sharded
    meshes, stride {1,2}, kernels {1x1, 3x3}, even and odd H (the kh=1
    no-halo fast path and the uneven-shard general path included)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import engine as engine_lib
        from repro.core import cim as cim_lib
        from repro.distributed import sharding as shd
        from repro.models import cnn
        from repro.core import rebranch

        cfg = cim_lib.CiMConfig(mode='ideal')
        eng_sh = engine_lib.get('pallas_sharded')
        eng_pl = engine_lib.get('pallas')
        key = jax.random.PRNGKey(0)
        checked = 0
        for n_dev in (1, 2, 4):
            mesh = jax.make_mesh((n_dev, 1), ('data', 'model'),
                                 devices=jax.devices()[:n_dev])
            for k in (1, 3):
                p = cnn.init_conv(jax.random.fold_in(key, k), k, 20, 12,
                                  rebranch.ReBranchSpec())
                w_q, w_scale = p['rom']['w_q'], p['rom']['w_scale']
                for stride in (1, 2):
                    for h in (16, 9):       # even (aligned) and odd (uneven)
                        x = jax.random.normal(
                            jax.random.fold_in(key, 100 + h), (2, h, 8, 20))
                        want = eng_pl.conv(cfg, x, w_q, w_scale,
                                           stride=stride)
                        with shd.use_mesh(mesh), mesh:
                            got = jax.jit(lambda x: eng_sh.conv(
                                cfg, x, w_q, w_scale, stride=stride))(x)
                        np.testing.assert_array_equal(
                            np.asarray(got), np.asarray(want),
                            err_msg=f'n={n_dev} k={k} s={stride} h={h}')
                        checked += 1
        print('OK', checked)
    """)
    assert "OK 24" in out


def test_sharded_conv_fidelity_modes():
    """Bit-parity holds in the non-ideal CiM modes too (the ADC transfer
    is per-(row, subarray) — the halo exchange preserves both).  Both
    sides are jit'd: eager vs jit of the SAME unsharded program already
    differs by 1 ulp in per_subarray mode (XLA fuses the f32 ADC chain
    differently), so the parity contract is under a common pipeline."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import engine as engine_lib
        from repro.core import cim as cim_lib, rebranch
        from repro.distributed import sharding as shd
        from repro.models import cnn

        mesh = jax.make_mesh((4, 1), ('data', 'model'),
                             devices=jax.devices()[:4])
        p = cnn.init_conv(jax.random.PRNGKey(0), 3, 20, 12,
                          rebranch.ReBranchSpec())
        w_q, w_scale = p['rom']['w_q'], p['rom']['w_scale']
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 20))
        for mode in ('per_subarray', 'bitserial'):
            cfg = cim_lib.CiMConfig(mode=mode)
            want = jax.jit(lambda x: engine_lib.get('pallas')
                           .conv(cfg, x, w_q, w_scale))(x)
            with shd.use_mesh(mesh), mesh:
                got = jax.jit(lambda x: engine_lib.get('pallas_sharded')
                              .conv(cfg, x, w_q, w_scale))(x)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=mode)
        print('OK')
    """, devices=4)
    assert "OK" in out


def test_sharded_rebranch_conv_and_ste_grad():
    """The fused sharded ReBranch conv matches its unsharded twin to
    1 ulp (the branch sketch is a float GEMM — BLAS reduction order is
    shape-dependent, so bitwise equality is a trunk-only property), and
    the sharded trunk's STE backward equals the vjp of the dequantised
    XLA conv."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import cim as cim_lib, rebranch
        from repro.distributed import sharding as shd
        from repro.kernels import halo_conv
        from repro.kernels.rebranch_conv import rebranch_conv_pallas
        from repro.models import cnn

        mesh = jax.make_mesh((4, 1), ('data', 'model'),
                             devices=jax.devices()[:4])
        cfg = cim_lib.CiMConfig(mode='ideal')
        p = cnn.init_conv(jax.random.PRNGKey(0), 3, 20, 12,
                          rebranch.ReBranchSpec())
        p['sram']['core'] = jax.random.normal(
            jax.random.PRNGKey(2), p['sram']['core'].shape) * 0.05
        rom, sram = p['rom'], p['sram']
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8, 20))

        want = jax.jit(lambda x: rebranch_conv_pallas(
            x, rom['w_q'], rom['w_scale'], rom['C'], sram['core'],
            rom['U'], cfg))(x)
        with shd.use_mesh(mesh), mesh:
            got = jax.jit(lambda x: halo_conv.sharded_rebranch_conv(
                x, rom['w_q'], rom['w_scale'], rom['C'], sram['core'],
                rom['U'], cfg, mesh=mesh, axis='data'))(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-6, atol=2e-6)

        w_q, w_scale = rom['w_q'], rom['w_scale']
        with shd.use_mesh(mesh), mesh:
            dx = jax.grad(lambda x: jnp.sum(halo_conv.sharded_trunk_conv(
                cfg, 2, 'SAME', mesh, 'data', x, w_q, w_scale)))(x)
        w_deq = w_q.astype(jnp.float32) * w_scale.astype(jnp.float32)
        want_dx = jax.grad(lambda x: jnp.sum(rebranch.conv_nhwc(
            x, w_deq, 2, 'SAME')))(x)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(want_dx),
                                   rtol=1e-4, atol=1e-4)
        print('OK')
    """, devices=4)
    assert "OK" in out


def test_darknet_and_resnet_trunk_convs_bit_identical():
    """Acceptance shape: every distinct trunk-conv geometry of DarkNet-19
    and ResNet-18 (at a reduced input) is bit-identical between the
    sharded and unsharded engines on a 4-device mesh."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import engine as engine_lib
        from repro.core import cim as cim_lib, rebranch
        from repro.distributed import sharding as shd
        from repro.models import cnn

        mesh = jax.make_mesh((4, 1), ('data', 'model'),
                             devices=jax.devices()[:4])
        cfg = cim_lib.CiMConfig(mode='ideal')
        eng_sh = engine_lib.get('pallas_sharded')
        eng_pl = engine_lib.get('pallas')
        key = jax.random.PRNGKey(0)

        # (c_in, c_out, k, h, stride) trunk-conv geometries at 32px input
        geoms = set()
        h, c_in = 32, 3
        for item in cnn.DARKNET19:
            if item == 'M':
                h //= 2
                continue
            c, k = item
            geoms.add((c_in, c, k, h, 1))
            c_in = c
        h, c_in = 32, 64                       # resnet18 stem is 3->64
        geoms.add((3, 64, 3, 32, 1))
        for c_out, blocks, stride in cnn.RESNET18_STAGES:
            geoms.add((c_in, c_out, 3, h, stride))       # conv1 (+proj 1x1)
            if stride != 1 or c_in != c_out:
                geoms.add((c_in, c_out, 1, h, stride))
            h //= stride
            geoms.add((c_out, c_out, 3, h, 1))           # conv2
            c_in = c_out

        for i, (ci, co, k, h, s) in enumerate(sorted(geoms)):
            # cap channels: parity is channel-independent, runtime is not
            ci_t, co_t = min(ci, 64), min(co, 64)
            p = cnn.init_conv(jax.random.fold_in(key, i), k, ci_t, co_t,
                              rebranch.ReBranchSpec())
            w_q, w_scale = p['rom']['w_q'], p['rom']['w_scale']
            x = jax.random.normal(jax.random.fold_in(key, 1000 + i),
                                  (1, h, h, ci_t))
            want = eng_pl.conv(cfg, x, w_q, w_scale, stride=s)
            with shd.use_mesh(mesh), mesh:
                got = jax.jit(lambda x: eng_sh.conv(
                    cfg, x, w_q, w_scale, stride=s))(x)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want),
                err_msg=f'cin={ci_t} cout={co_t} k={k} h={h} s={s}')
        print('OK', len(geoms))
    """, devices=4)
    assert "OK" in out


def test_compile_model_mesh_cnn_forward():
    """deploy.compile_model(cfg, mesh=...) serves a whole H-sharded CNN:
    forward matches the unsharded engine to f32 tolerance (the XLA branch
    convs repartition under GSPMD, so full-model parity is allclose, not
    bit-equal — the trunk convs themselves are covered bit-exactly above).
    """
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro import deploy
        from repro.core import cim as cim_lib, rebranch
        from repro.models import cnn

        mesh = jax.make_mesh((4, 1), ('data', 'model'),
                             devices=jax.devices()[:4])
        spec = dataclasses.replace(rebranch.ReBranchSpec(),
                                   cim=cim_lib.CiMConfig(mode='ideal'))
        for name in ('darknet19', 'resnet18'):
            cfg = cnn.CNNConfig(name=name, input_size=32, rebranch=spec,
                                fuse_bn_act=True)
            sharded = deploy.compile_model(cfg, engine='pallas_sharded',
                                           mesh=mesh)
            plain = deploy.compile_model(cfg, engine='pallas')
            params = plain.init(jax.random.PRNGKey(0))
            x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
            want = plain.forward(params, x)
            got = jax.jit(sharded.forward)(params, x)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-4, atol=2e-4, err_msg=name)
        print('OK')
    """, devices=4)
    assert "OK" in out


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-v"]))
