"""MoE dispatch correctness: the capacity-based one-hot dispatch/combine
must reproduce a direct per-token top-k computation when capacity covers
demand, and degrade by dropping (never corrupting) when it doesn't."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe
from repro.models.config import ArchConfig


def _cfg(**kw):
    base = dict(name="m", family="moe", num_layers=1, d_model=32,
                num_heads=4, num_kv_heads=4, d_ff=16, moe_d_ff=16,
                vocab_size=64, num_experts=4, num_experts_per_tok=2,
                moe_group_size=8, moe_capacity_factor=8.0,  # no drops
                dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def _dense_reference(params, x, cfg):
    """Every token through its top-k experts directly (no dispatch)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]["sram"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gates = gates / gates.sum(-1, keepdims=True)

    # run EVERY expert on EVERY token, then pick
    def one_expert(e):
        # per-expert leaves have a leading E dim (3D); C/U are shared (2D)
        slice_p = jax.tree.map(
            lambda a: a[e:e + 1] if a.ndim == 3 else a, params["experts"])
        xe = xf[None]                                     # [1, T, d]
        hg = moe.apply_expert_linear(slice_p["gate"], xe)
        hu = moe.apply_expert_linear(slice_p["up"], xe)
        h = jax.nn.silu(hg) * hu
        return moe.apply_expert_linear(slice_p["down"], h)[0]

    all_out = jnp.stack([one_expert(e) for e in range(cfg.num_experts)])
    t = xf.shape[0]
    y = jnp.zeros_like(xf)
    for j in range(cfg.num_experts_per_tok):
        y = y + gates[:, j, None] * all_out[idx[:, j], jnp.arange(t)]
    return y.reshape(b, s, d)


class TestMoEDispatch:
    def test_matches_dense_reference_when_capacity_ample(self):
        cfg = _cfg()
        key = jax.random.PRNGKey(0)
        params = moe.init_moe_block(key, cfg)
        # give the cores signal so experts differ
        params["experts"]["gate"]["sram"]["core"] = jax.random.normal(
            jax.random.PRNGKey(1),
            params["experts"]["gate"]["sram"]["core"].shape) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32))
        got = moe.apply_moe_block(params, x, cfg)
        want = _dense_reference(params, x, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-2, atol=5e-2)

    def test_capacity_drop_is_partial_not_corrupt(self):
        """With tiny capacity, output ~= reference with some tokens' expert
        contributions missing — never garbage."""
        cfg = _cfg(moe_capacity_factor=0.5)
        params = moe.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32))
        got = moe.apply_moe_block(params, x, cfg)
        assert bool(jnp.all(jnp.isfinite(got)))
        # dropped-token norm can only SHRINK vs ample capacity
        cfg2 = _cfg(moe_capacity_factor=8.0)
        full = moe.apply_moe_block(params, x, cfg2)
        assert float(jnp.linalg.norm(got)) <= float(
            jnp.linalg.norm(full)) * 1.05

    def test_shared_experts_added(self):
        cfg = _cfg(num_shared_experts=2)
        params = moe.init_moe_block(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 32))
        y = moe.apply_moe_block(params, x, cfg)
        assert "shared" in params
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_aux_loss_decreases_with_balance(self):
        cfg = _cfg()
        params = moe.init_moe_block(jax.random.PRNGKey(0), cfg)
        # positive inputs so boosting one router column is sign-stable
        x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (2, 64, 32)))
        bal = float(moe.aux_load_balance_loss(params, x, cfg))
        # force imbalance: expert 0 wins for every (positive) token
        params["router"]["sram"]["w"] = (
            params["router"]["sram"]["w"].at[:, 0].add(10.0))
        imbal = float(moe.aux_load_balance_loss(params, x, cfg))
        assert imbal > bal

    def test_stacked_trunk_grad_is_ste(self):
        spec = _cfg().rebranch
        p = moe.init_expert_linear(jax.random.PRNGKey(0), 3, 16, 8, spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 16))

        def f(x):
            return jnp.sum(moe._stacked_trunk_matmul(
                x, p["rom"]["w_q"], p["rom"]["w_scale"]))

        dx = jax.grad(f)(x)
        w_deq = (np.asarray(p["rom"]["w_q"], np.float32)
                 * np.asarray(p["rom"]["w_scale"], np.float32))
        want = np.einsum("ecf,edf->ecd", np.ones((3, 4, 8), np.float32),
                         w_deq)
        np.testing.assert_allclose(np.asarray(dx), want, rtol=1e-4,
                                   atol=1e-4)
