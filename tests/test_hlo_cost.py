"""hlo_cost parser: exact FLOPs on known programs (matmul, scan, nested
scan, int8 dot, conv) and collective-byte extraction."""

import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def _cost(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return hlo_cost.analyse_text(txt)


class TestFlops:
    def test_plain_matmul(self):
        a, b = jnp.zeros((128, 64)), jnp.zeros((64, 32))
        c = _cost(lambda a, b: a @ b, a, b)
        assert c["flops"] == 2 * 128 * 64 * 32

    def test_int8_dot_counted(self):
        a = jnp.zeros((64, 32), jnp.int8)
        b = jnp.zeros((32, 16), jnp.int8)
        c = _cost(lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32), a, b)
        assert c["flops"] == 2 * 64 * 32 * 16

    def test_scan_trip_count(self):
        x, w = jnp.zeros((32, 32)), jnp.zeros((32, 32))

        def g(x, w):
            return jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                                length=7)[0]
        c = _cost(g, x, w)
        assert c["flops"] == 7 * 2 * 32 ** 3

    def test_nested_scan(self):
        x, w = jnp.zeros((16, 16)), jnp.zeros((16, 16))

        def g(x, w):
            def outer(c, _):
                inner = jax.lax.scan(lambda ci, _: (ci @ w, None), c,
                                     None, length=3)[0]
                return inner, None
            return jax.lax.scan(outer, x, None, length=5)[0]
        c = _cost(g, x, w)
        assert c["flops"] == 15 * 2 * 16 ** 3

    def test_conv_flops(self):
        x = jnp.zeros((1, 8, 8, 4))
        k = jnp.zeros((3, 3, 4, 8))

        def f(x, k):
            return jax.lax.conv_general_dilated(
                x, k, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
        c = _cost(f, x, k)
        # 2 * out_elems * (kh*kw*cin)
        assert c["flops"] == 2 * (8 * 8 * 8) * (3 * 3 * 4)


class TestCollectives:
    def test_sharded_allreduce_bytes(self):
        import subprocess, sys, os, textwrap
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "src")
        code = textwrap.dedent("""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch import hlo_cost
            mesh = jax.make_mesh((8,), ('x',))
            def f(a, b):
                y = a @ b                     # contraction sharded -> psum
                return y
            a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
            b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
            with mesh:
                c = jax.jit(f, in_shardings=(
                    NamedSharding(mesh, P(None, 'x')),
                    NamedSharding(mesh, P('x', None)))).lower(a, b).compile()
            costs = hlo_cost.analyse_text(c.as_text())
            assert costs['collective_bytes'] >= 32 * 16 * 4, costs
            print('OK', costs['collective_bytes'])
        """)
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=180)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout
