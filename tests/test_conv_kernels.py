"""Parity harness for the fused ReBranch conv Pallas kernels.

Three layers of truth, in order of authority:
  1. jax.lax.conv golden reference — catches im2col plumbing bugs
     (padding split, stride windows, tap/channel column order).
  2. core.cim.cim_conv_model — the macro fidelity oracle; the int8 conv
     kernel must agree in every CiM mode on the shared shapes.
  3. ref.trunk_conv_ref / ref.rebranch_conv_ref — blocked-quantisation
     oracles with the fused kernels' exact numerics.
Plus gradient-path checks: the STE backward of both trunk_conv dispatches
equals the vjp of the dequantised XLA conv.

Everything runs in Pallas interpret mode (CPU).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cim as cim_lib
from repro.core import rebranch
from repro.kernels import ops, ref
from repro.kernels.rebranch_conv import (
    cim_conv_pallas, rebranch_conv_pallas, trunk_conv_pallas,
)
from repro.models import cnn

# strides {1, 2} x kernel sizes {1, 3} x SAME/VALID, non-multiple-of-block
# channel counts (20, 33 vs rows_per_subarray=128 / block_k=512)
SWEEP = [
    (1, 1, "SAME"), (1, 1, "VALID"),
    (3, 1, "SAME"), (3, 1, "VALID"),
    (1, 2, "SAME"), (3, 2, "SAME"), (3, 2, "VALID"),
]


def _rand_int8(key, shape, scale=25):
    return jnp.clip(jnp.round(jax.random.normal(key, shape) * scale),
                    -127, 127).astype(jnp.int8)


def _xla_conv(x, w, stride, padding):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _quant_w(w):
    absmax = jnp.max(jnp.abs(w), axis=(0, 1, 2), keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    w_q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return w_q, scale


# ---------------------------------------------------------------------------
# 1. int8 conv kernel vs jax.lax.conv golden (ideal mode, f32 accumulation)
# ---------------------------------------------------------------------------

class TestCimConvGolden:
    @pytest.mark.parametrize("k,stride,padding", SWEEP)
    @pytest.mark.parametrize("c_in,c_out", [(20, 9), (33, 17)])
    def test_ideal_matches_xla_conv(self, k, stride, padding, c_in, c_out):
        k1, k2 = jax.random.split(jax.random.PRNGKey(k * 7 + stride + c_in))
        x = _rand_int8(k1, (2, 9, 9, c_in))
        w = _rand_int8(k2, (k, k, c_in, c_out), scale=30)
        got = cim_conv_pallas(x, w, cim_lib.CiMConfig(mode="ideal"),
                              stride=stride, padding=padding, interpret=True)
        want = _xla_conv(x.astype(jnp.float32), w.astype(jnp.float32),
                         stride, padding)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=1e-4)

    def test_im2col_model_matches_xla_conv(self):
        """The core model itself agrees with lax.conv (not just the kernel)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x = _rand_int8(k1, (1, 11, 11, 13))
        w = _rand_int8(k2, (3, 3, 13, 5), scale=30)
        got = cim_lib.cim_conv_model(x, w, cim_lib.CiMConfig(mode="ideal"),
                                     stride=2, padding="SAME")
        want = _xla_conv(x.astype(jnp.float32), w.astype(jnp.float32),
                         2, "SAME")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# 2. int8 conv kernel vs core.cim fidelity modes
# ---------------------------------------------------------------------------

class TestCimConvFidelity:
    @pytest.mark.parametrize("mode", ["ideal", "per_subarray", "bitserial"])
    @pytest.mark.parametrize("k,stride,padding", [
        (1, 1, "SAME"), (3, 1, "SAME"), (3, 2, "SAME"), (3, 2, "VALID"),
    ])
    def test_matches_core_model(self, mode, k, stride, padding):
        cfg = cim_lib.CiMConfig(mode=mode)
        k1, k2 = jax.random.split(jax.random.PRNGKey(k + stride))
        x = _rand_int8(k1, (1, 8, 8, 20))
        w = _rand_int8(k2, (k, k, 20, 9), scale=30)
        got = cim_conv_pallas(x, w, cfg, stride=stride, padding=padding,
                              interpret=True)
        want = ref.cim_conv_ref(x, w, cfg, stride, padding)
        # identical math; atol covers f32 sum-order inside the blocked pass
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=0.25)

    def test_block_shape_invariance(self):
        """Result must not depend on the BlockSpec tiling (subarray
        boundaries align to global K offsets regardless of block_k)."""
        cfg = cim_lib.CiMConfig(mode="per_subarray")
        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        x = _rand_int8(k1, (1, 8, 8, 40))            # R = 360: pads ragged
        w = _rand_int8(k2, (3, 3, 40, 9), scale=30)
        want = ref.cim_conv_ref(x, w, cfg, 1, "SAME")
        for bm, bn, bk in [(64, 64, 128), (128, 128, 512), (32, 128, 256)]:
            got = cim_conv_pallas(x, w, cfg, stride=1, padding="SAME",
                                  block_m=bm, block_n=bn, block_k=bk,
                                  interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-2)


# ---------------------------------------------------------------------------
# 3. fused float-in kernels vs blocked-quant oracles
# ---------------------------------------------------------------------------

class TestFusedConv:
    def _make(self, key, c_in=20, c_out=9, k=3, d=4, u_ratio=4):
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (2, 8, 8, c_in))
        w = jax.random.normal(ks[1], (k, k, c_in, c_out)) / np.sqrt(
            k * k * c_in)
        w_q, w_scale = _quant_w(w)
        c_c, c_u = max(1, c_in // d), max(1, c_out // u_ratio)
        c = jax.random.normal(ks[2], (1, 1, c_in, c_c)) / np.sqrt(c_in)
        core = jax.random.normal(ks[3], (k, k, c_c, c_u)) * 0.1
        u = jax.random.normal(ks[0], (1, 1, c_u, c_out)) / np.sqrt(c_u)
        return x, w_q, w_scale, c, core, u

    @pytest.mark.parametrize("mode", ["ideal", "per_subarray"])
    @pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                                (2, "VALID")])
    def test_trunk_conv_matches_oracle(self, mode, stride, padding):
        cfg = cim_lib.CiMConfig(mode=mode)
        x, w_q, w_scale, *_ = self._make(jax.random.PRNGKey(stride))
        got = trunk_conv_pallas(x, w_q, w_scale, cfg, stride=stride,
                                padding=padding, interpret=True)
        want = ref.trunk_conv_ref(x, w_q, w_scale, cfg, stride, padding)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("k", [1, 3])
    @pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                                (1, "VALID"), (2, "VALID")])
    def test_rebranch_conv_matches_oracle(self, k, stride, padding):
        args = self._make(jax.random.PRNGKey(k * 10 + stride), k=k)
        got = rebranch_conv_pallas(*args, stride=stride, padding=padding,
                                   interpret=True)
        want = ref.rebranch_conv_ref(*args, stride=stride, padding=padding)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_rebranch_conv_ragged_channels(self):
        """Non-multiple-of-block channel counts pad cleanly end to end."""
        args = self._make(jax.random.PRNGKey(3), c_in=33, c_out=17)
        got = rebranch_conv_pallas(*args, stride=2, interpret=True)
        want = ref.rebranch_conv_ref(*args, stride=2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_unfused_layer_semantics(self):
        """Fused kernel ~= models.cnn.apply_conv (different activation-quant
        granularity: per-patch-row vs per-pixel, so tolerance is loose)."""
        spec = rebranch.ReBranchSpec()
        p = cnn.init_conv(jax.random.PRNGKey(0), 3, 32, 16, spec)
        p["sram"]["core"] = jax.random.normal(
            jax.random.PRNGKey(2), p["sram"]["core"].shape) * 0.05
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 32))
        got = rebranch_conv_pallas(
            x, p["rom"]["w_q"], p["rom"]["w_scale"], p["rom"]["C"],
            p["sram"]["core"], p["rom"]["U"], stride=1, interpret=True)
        want = cnn.apply_conv(p, x, spec, stride=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0.05, atol=0.05)


# ---------------------------------------------------------------------------
# 4. dispatch + STE gradient path
# ---------------------------------------------------------------------------

class TestConvDispatch:
    def _layer(self, key, c_in=20, c_out=12):
        spec = rebranch.ReBranchSpec()
        p = cnn.init_conv(key, 3, c_in, c_out, spec)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, 6, c_in))
        return p, x

    @pytest.mark.parametrize("impl", ["int8_native", "dequant", "pallas"])
    def test_trunk_impls_agree(self, impl):
        p, x = self._layer(jax.random.PRNGKey(0))
        spec = dataclasses.replace(rebranch.ReBranchSpec(), trunk_impl=impl)
        y = cnn.apply_conv(p, x, spec, stride=2)
        ref_out = cnn.apply_conv(p, x, rebranch.ReBranchSpec(), stride=2)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref_out),
                                   rtol=0.05, atol=0.05)

    @pytest.mark.parametrize("path", ["pallas", "int8_native"])
    @pytest.mark.parametrize("stride", [1, 2])
    def test_trunk_conv_backward_is_ste(self, path, stride):
        """dx through the frozen trunk equals the vjp of the dequantised
        XLA conv (conv is linear in x, so grad is x-independent)."""
        p, x = self._layer(jax.random.PRNGKey(4))
        w_q, w_scale = p["rom"]["w_q"], p["rom"]["w_scale"]
        cfg = cim_lib.CiMConfig(mode="ideal")
        op = ops.trunk_conv if path == "pallas" else rebranch.trunk_conv

        def f(x):
            return jnp.sum(op(cfg, stride, "SAME", x, w_q, w_scale))

        dx = jax.grad(f)(x)
        w_deq = w_q.astype(jnp.float32) * w_scale.astype(jnp.float32)

        def golden(x):
            return jnp.sum(_xla_conv(x, w_deq, stride, "SAME"))

        want = jax.grad(golden)(x)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_gradients_flow_to_branch_core_only(self):
        """Under every impl, d(loss)/d(core) is nonzero and no ROM grads
        exist (partition() strips them)."""
        for impl in ["int8_native", "pallas"]:
            spec = dataclasses.replace(rebranch.ReBranchSpec(),
                                       trunk_impl=impl)
            p, x = self._layer(jax.random.PRNGKey(6))
            t, f = rebranch.partition(p)

            def loss(t):
                y = cnn.apply_conv(rebranch.combine(t, f), x, spec)
                return jnp.sum(y ** 2)

            g = jax.grad(loss)(t)
            assert float(jnp.sum(jnp.abs(g["sram"]["core"]))) > 0, impl

    def test_jit_and_vmap_safe(self):
        """The pallas conv path works under jit (models wrap it in jit'd
        train steps)."""
        p, x = self._layer(jax.random.PRNGKey(7))
        spec = dataclasses.replace(rebranch.ReBranchSpec(),
                                   trunk_impl="pallas")
        y = jax.jit(lambda x: cnn.apply_conv(p, x, spec))(x)
        assert bool(jnp.all(jnp.isfinite(y)))
