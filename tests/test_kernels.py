"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes/dtypes per the kernel-validation contract; every case is
assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import cim as cim_lib
from repro.core import rebranch
from repro.kernels import ref
from repro.kernels.cim_matmul import cim_matmul_pallas
from repro.kernels.rebranch_matmul import rebranch_matmul_pallas
from repro.kernels import ops


def _rand_int8(key, shape, scale=25):
    return jnp.clip(jnp.round(jax.random.normal(key, shape) * scale),
                    -127, 127).astype(jnp.int8)


# ---------------------------------------------------------------------------
# cim_matmul kernel vs oracle
# ---------------------------------------------------------------------------

class TestCimMatmulKernel:
    @pytest.mark.parametrize("mode", ["ideal", "per_subarray", "bitserial"])
    @pytest.mark.parametrize("shape", [
        (8, 128, 16), (4, 256, 32), (16, 512, 8),
        (3, 300, 7),            # ragged: padding on every axis
        (1, 128, 1),            # degenerate
    ])
    def test_matches_oracle(self, mode, shape):
        m, k, n = shape
        k1, k2 = jax.random.split(jax.random.PRNGKey(m * k + n))
        x = _rand_int8(k1, (m, k))
        w = _rand_int8(k2, (k, n), scale=30)
        cfg = cim_lib.CiMConfig(mode=mode)
        got = cim_matmul_pallas(x, w, cfg, interpret=True)
        want = ref.cim_matmul_ref(x, w, cfg)
        # outputs are O(1e4) integer-ish sums; atol covers f32 sum-order
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=0.25)

    @pytest.mark.parametrize("block", [(64, 64, 128), (128, 128, 256),
                                       (32, 256, 512)])
    def test_block_shape_invariance(self, block):
        """Result must not depend on the BlockSpec tiling (ideal mode is
        bit-exact; subarray modes align to global K offsets)."""
        bm, bn, bk = block
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x = _rand_int8(k1, (48, 640))
        w = _rand_int8(k2, (640, 96))
        for mode in ["ideal", "per_subarray"]:
            cfg = cim_lib.CiMConfig(mode=mode)
            got = cim_matmul_pallas(x, w, cfg, block_m=bm, block_n=bn,
                                    block_k=bk, interpret=True)
            want = ref.cim_matmul_ref(x, w, cfg)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-2)

    def test_ideal_mode_bit_exact(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        x = jax.random.randint(k1, (8, 384), -127, 128).astype(jnp.int8)
        w = jax.random.randint(k2, (384, 24), -127, 128).astype(jnp.int8)
        cfg = cim_lib.CiMConfig(mode="ideal")
        got = cim_matmul_pallas(x, w, cfg, interpret=True)
        want = np.asarray(x, np.int64) @ np.asarray(w, np.int64)
        np.testing.assert_array_equal(np.asarray(got, np.int64), want)

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 20), k=st.integers(1, 300), n=st.integers(1, 40))
    def test_property_ideal_any_shape(self, m, k, n):
        k1, k2 = jax.random.split(jax.random.PRNGKey(m + 31 * k + 997 * n))
        x = _rand_int8(k1, (m, k))
        w = _rand_int8(k2, (k, n))
        cfg = cim_lib.CiMConfig(mode="ideal")
        got = cim_matmul_pallas(x, w, cfg, interpret=True)
        want = np.asarray(x, np.int64) @ np.asarray(w, np.int64)
        np.testing.assert_array_equal(np.asarray(got, np.int64), want)


# ---------------------------------------------------------------------------
# fused rebranch kernel vs oracle
# ---------------------------------------------------------------------------

class TestReBranchKernel:
    def _make(self, key, m, k, n, d=4, u_ratio=4, dtype=jnp.float32):
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (m, k), dtype)
        w = jax.random.normal(ks[1], (k, n)) / np.sqrt(k)
        from repro.core.quant import quantize_weights
        w_q, w_scale = quantize_weights(w, axis=0)
        c = (jax.random.normal(ks[2], (k, max(1, k // d)), dtype)
             / np.sqrt(k))
        core = jax.random.normal(ks[3], (max(1, k // d), max(1, n // u_ratio)),
                                 dtype)
        uu = (jax.random.normal(ks[4], (max(1, n // u_ratio), n), dtype)
              / np.sqrt(max(1, n // u_ratio)))
        return x, w_q, w_scale, c, core, uu

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(8, 512, 128), (16, 1024, 64),
                                       (5, 300, 48)])
    def test_matches_oracle(self, dtype, shape):
        m, k, n = shape
        args = self._make(jax.random.PRNGKey(m + k + n), m, k, n, dtype=dtype)
        got = rebranch_matmul_pallas(*args, block_k=512, interpret=True)
        want = ref.rebranch_matmul_ref(*args, block_k=512)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_matches_unfused_layer_semantics(self):
        """Fused kernel ~= core.rebranch.apply_linear (different activation-
        quant granularity: per-block vs per-row, so tolerance is loose)."""
        spec = rebranch.ReBranchSpec()
        p = rebranch.init_linear(jax.random.PRNGKey(0), 512, 128, spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 512))
        p["sram"]["core"] = jax.random.normal(jax.random.PRNGKey(2),
                                              p["sram"]["core"].shape) * 0.05
        got = rebranch_matmul_pallas(
            x, p["rom"]["w_q"], p["rom"]["w_scale"], p["rom"]["C"],
            p["sram"]["core"], p["rom"]["U"], interpret=True)
        want = rebranch.apply_linear(p, x, spec)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0.05, atol=0.05)

    def test_block_invariance(self):
        args = self._make(jax.random.PRNGKey(9), 16, 1024, 128)
        outs = [
            np.asarray(rebranch_matmul_pallas(
                *args, block_m=bm, block_n=bn, block_k=512, interpret=True))
            for bm, bn in [(8, 64), (16, 128)]
        ]
        np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ops wrappers
# ---------------------------------------------------------------------------

class TestOps:
    def test_trunk_matmul_pallas_grad_is_ste(self):
        spec = rebranch.ReBranchSpec()
        p = rebranch.init_linear(jax.random.PRNGKey(0), 256, 64, spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
        cfg = cim_lib.CiMConfig(mode="ideal")

        def f(x):
            return jnp.sum(ops.trunk_matmul_pallas(
                cfg, x, p["rom"]["w_q"], p["rom"]["w_scale"]))

        dx = jax.grad(f)(x)
        w_deq = (np.asarray(p["rom"]["w_q"], np.float32)
                 * np.asarray(p["rom"]["w_scale"], np.float32))
        want = np.ones((4, 64), np.float32) @ w_deq.T
        np.testing.assert_allclose(np.asarray(dx), want, rtol=1e-4, atol=1e-4)

    def test_pallas_impl_in_layer(self):
        """ReBranchSpec(trunk_impl='pallas') runs end-to-end in a layer."""
        import dataclasses as dc
        spec = dc.replace(rebranch.ReBranchSpec(), trunk_impl="pallas")
        p = rebranch.init_linear(jax.random.PRNGKey(0), 256, 64, spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
        y = rebranch.apply_linear(p, x, spec)
        want = rebranch.apply_linear(p, x, rebranch.ReBranchSpec())
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
