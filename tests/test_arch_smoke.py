"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs.  Full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import rebranch
from repro.models import api

B, S = 2, 16


def _batch(cfg, key):
    b = {"tokens": jax.random.randint(
        key, (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S),
        0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.float32)
    return b


def _labels(cfg, key):
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_forward_smoke(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    logits = api.forward(params, _batch(cfg, key), cfg)
    want = ((B, S, cfg.num_codebooks, cfg.vocab_size)
            if cfg.num_codebooks else (B, S, cfg.vocab_size))
    assert logits.shape == want
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_train_step_smoke(arch):
    """One branch-only train step: loss is finite, decreases over 3 steps,
    and ONLY sram params change (ROM is immutable)."""
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = api.init(key, cfg)
    batch = _batch(cfg, key)
    labels = _labels(cfg, jax.random.PRNGKey(2))
    trainable, frozen = rebranch.partition(params)

    def loss_fn(t):
        logits = api.forward(rebranch.combine(t, frozen), batch, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)
        return -jnp.mean(ll)

    step = jax.jit(lambda t: (loss_fn(t), jax.grad(loss_fn)(t)))
    losses = []
    t = trainable
    for _ in range(3):
        loss, g = step(t)
        losses.append(float(loss))
        t = jax.tree.map(lambda p, gg: p - 0.5 * gg, t, g)
    assert np.isfinite(losses).all(), f"{arch}: NaN loss {losses}"
    assert losses[-1] < losses[0], f"{arch}: loss not decreasing {losses}"


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_serve_smoke(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(3)
    params = api.init(key, cfg)
    cache = api.init_cache(cfg, B, S + 8, dtype=jnp.float32)
    logits, cache = api.prefill(params, _batch(cfg, key), cfg, cache)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok_shape = (B, 1, cfg.num_codebooks) if cfg.num_codebooks else (B, 1)
    tok = jax.random.randint(key, tok_shape, 0, cfg.vocab_size)
    logits2, cache = api.decode_step(params, tok, cfg, cache)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", configs.ALL_ARCHS)
def test_rom_dominates(arch):
    """paper: >90% of parameters live in ROM (checked on smoke configs
    with their small vocab; full configs are more ROM-heavy still)."""
    cfg = configs.get_smoke(arch)
    params = api.init(jax.random.PRNGKey(0), cfg)
    n_sram = rebranch.trainable_count(params)
    n_rom = rebranch.frozen_count(params)
    frac = n_rom / (n_rom + n_sram)
    assert frac > 0.80, f"{arch}: ROM fraction {frac:.2f}"


def test_paper_model_param_counts():
    """The paper's own models land near their published sizes."""
    from repro.models import cnn
    from repro.configs.paper_models import PAPER_MODELS
    n_dn, _ = cnn.count_macs_and_params(
        *cnn.MODEL_REGISTRY["darknet19"], PAPER_MODELS["darknet19"])
    assert 40e6 < n_dn < 52e6          # paper: "YOLO has 46 M weights"
    n_ty, _ = cnn.count_macs_and_params(
        *cnn.MODEL_REGISTRY["tiny_yolo"], PAPER_MODELS["tiny_yolo"])
    assert 9e6 < n_ty < 16e6           # paper: "Tiny-YOLO has 11.3 M"
