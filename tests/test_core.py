"""Unit tests for core: quantization, CiM model, ReBranch, ROM utilities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import cim, quant, rebranch, rom

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# quant
# ---------------------------------------------------------------------------

class TestQuant:
    def test_weight_roundtrip_error_bounded(self):
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (64, 32))
        w_q, s = quant.quantize_weights(w, axis=0)
        err = jnp.abs(quant.dequantize(w_q, s) - w)
        # max error <= half an LSB per channel
        assert float(jnp.max(err / s)) <= 0.5 + 1e-3

    def test_activation_quant_shapes(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 7, 16))
        x_q, s = quant.quantize_activations(x)
        assert x_q.shape == x.shape and x_q.dtype == jnp.int8
        assert s.shape == (4, 7, 1)

    def test_fake_quant_gradient_is_straight_through(self):
        x = jnp.array([0.3, -1.2, 2.5])
        g = jax.grad(lambda v: jnp.sum(quant.fake_quant_ste(v) ** 2))(x)
        # STE: d/dx sum(fq(x)^2) ~= 2*fq(x)
        np.testing.assert_allclose(np.asarray(g),
                                   2 * np.asarray(quant.fake_quant_ste(x)),
                                   rtol=1e-5)

    def test_int8_matmul_matches_float(self):
        key = jax.random.PRNGKey(2)
        a = jax.random.randint(key, (8, 16), -127, 128, jnp.int8)
        b = jax.random.randint(key, (16, 4), -127, 128, jnp.int8)
        out = quant.int8_matmul(a, b)
        ref = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
        np.testing.assert_array_equal(np.asarray(out, np.int64), ref)


# ---------------------------------------------------------------------------
# CiM macro model
# ---------------------------------------------------------------------------

class TestCiM:
    def _rand_int8(self, key, shape):
        return jax.random.randint(key, shape, -127, 128).astype(jnp.int8)

    def test_ideal_mode_exact(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        a = self._rand_int8(k1, (4, 256))
        w = self._rand_int8(k2, (256, 8))
        cfg = cim.CiMConfig(mode="ideal")
        out = cim.cim_matmul_model(a, w, cfg)
        ref = np.asarray(a, np.int64) @ np.asarray(w, np.int64)
        np.testing.assert_array_equal(np.asarray(out, np.int64), ref)

    @pytest.mark.parametrize("mode", ["per_subarray", "bitserial"])
    @pytest.mark.parametrize("k", [128, 256, 100, 300])
    def test_nonideal_close_to_exact(self, mode, k):
        """5-bit ADC noise on realistic activations stays small relative to
        the output scale (the paper reports <0.4% accuracy loss)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(42))
        # realistic: activations concentrated, not full-scale
        a = jnp.clip(jnp.round(jax.random.normal(k1, (8, k)) * 20), -127, 127
                     ).astype(jnp.int8)
        w = jnp.clip(jnp.round(jax.random.normal(k2, (k, 16)) * 30), -127, 127
                     ).astype(jnp.int8)
        cfg = cim.CiMConfig(mode=mode)
        out = np.asarray(cim.cim_matmul_model(a, w, cfg))
        ref = np.asarray(a, np.float64) @ np.asarray(w, np.float64)
        scale = np.std(ref) + 1e-6
        rel = np.abs(out - ref) / scale
        assert np.mean(rel) < 0.25, f"mode={mode} k={k} mean rel err {np.mean(rel)}"

    def test_bitserial_exact_with_infinite_adc(self):
        """With enough ADC bits the bit-serial decomposition is EXACT —
        validates the offset-binary algebra and correction terms."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(7))
        a = self._rand_int8(k1, (4, 200))       # non-multiple of 128: padding
        w = self._rand_int8(k2, (200, 8))
        cfg = cim.CiMConfig(mode="bitserial", adc_bits=20, adc_range_frac=1.0)
        out = np.asarray(cim.cim_matmul_model(a, w, cfg))
        ref = np.asarray(a, np.float64) @ np.asarray(w, np.float64)
        # outputs are O(1e5); residual error is f32 rounding of the 20-bit
        # ADC lsb, not a modelling error
        np.testing.assert_allclose(out, ref, atol=2.0)

    def test_per_subarray_exact_with_infinite_adc(self):
        """Within the engineered analogue range (psums from realistic,
        concentrated distributions) an infinite-resolution ADC makes the
        per-subarray model exact up to f32 rounding."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(8))
        a = jnp.clip(jnp.round(jax.random.normal(k1, (4, 384)) * 20),
                     -127, 127).astype(jnp.int8)
        w = jnp.clip(jnp.round(jax.random.normal(k2, (384, 8)) * 30),
                     -127, 127).astype(jnp.int8)
        cfg = cim.CiMConfig(mode="per_subarray", adc_bits=24,
                            psum_range_frac=1.25)   # engineering margin
        out = np.asarray(cim.cim_matmul_model(a, w, cfg))
        ref = np.asarray(a, np.float64) @ np.asarray(w, np.float64)
        # f32 rounding at 24-bit ADC granularity, not a modelling error
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2.0)

    def test_adc_transfer_levels(self):
        cfg = cim.CiMConfig(adc_bits=5)
        x = jnp.linspace(0.0, 384.0, 1000)
        y = np.asarray(cim.adc_transfer(x, 384.0, cfg))
        assert len(np.unique(y)) <= 32  # 5-bit
        assert y.min() >= 0 and y.max() <= 384.0

    def test_macro_count(self):
        # one 128x256 macro holds 32768 cells = 4096 8-bit weights
        assert cim.macro_count(4096) == 1
        assert cim.macro_count(4097) == 2

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 5), k=st.integers(1, 300), n=st.integers(1, 24))
    def test_property_ideal_equals_int_matmul(self, m, k, n):
        k1, k2 = jax.random.split(jax.random.PRNGKey(m * 1000 + k * 10 + n))
        a = self._rand_int8(k1, (m, k))
        w = self._rand_int8(k2, (k, n))
        out = cim.cim_matmul_model(a, w, cim.CiMConfig(mode="ideal"))
        ref = np.asarray(a, np.int64) @ np.asarray(w, np.int64)
        np.testing.assert_array_equal(np.asarray(out, np.int64), ref)


# ---------------------------------------------------------------------------
# ReBranch
# ---------------------------------------------------------------------------

SPEC = rebranch.ReBranchSpec()


class TestReBranch:
    def test_partition_combine_roundtrip(self):
        p = rebranch.init_linear(jax.random.PRNGKey(0), 32, 16, SPEC)
        t, f = rebranch.partition(p)
        assert t["rom"]["w_q"] is None and f["rom"]["w_q"] is not None
        assert t["sram"]["core"] is not None and f["sram"]["core"] is None
        merged = rebranch.combine(t, f)
        assert jax.tree.structure(merged) == jax.tree.structure(p)
        for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fresh_branch_is_identity_of_trunk(self):
        """core=0 => output equals the quantised trunk alone."""
        p = rebranch.init_linear(jax.random.PRNGKey(0), 64, 32, SPEC)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
        y = rebranch.apply_linear(p, x, SPEC)
        w_deq = (p["rom"]["w_q"].astype(jnp.float32)
                 * p["rom"]["w_scale"].astype(jnp.float32))
        ref = np.asarray(quant.fake_quant_ste(x)) @ np.asarray(w_deq)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=0.03, atol=0.05)

    def test_branch_param_budget_is_1_over_16(self):
        p = rebranch.init_linear(jax.random.PRNGKey(0), 256, 256, SPEC)
        trunk = p["rom"]["w_q"].size
        core = p["sram"]["core"].size
        assert core * 16 == trunk

    def test_gradients_only_flow_to_sram(self):
        p = rebranch.init_linear(jax.random.PRNGKey(0), 32, 16, SPEC)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
        t, f = rebranch.partition(p)

        def loss(t):
            y = rebranch.apply_linear(rebranch.combine(t, f), x, SPEC)
            return jnp.sum(y ** 2)

        g = jax.grad(loss)(t)
        assert float(jnp.sum(jnp.abs(g["sram"]["core"]))) > 0

    def test_trunk_matmul_backward_is_ste(self):
        """dx through the frozen int8 trunk equals g @ dequant(w)^T."""
        key = jax.random.PRNGKey(3)
        p = rebranch.init_linear(key, 48, 24, SPEC)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 48))
        cfg = SPEC.cim

        def f(x):
            return jnp.sum(rebranch.trunk_matmul(
                cfg, None, x, p["rom"]["w_q"], p["rom"]["w_scale"]))

        dx = jax.grad(f)(x)
        w_deq = np.asarray(p["rom"]["w_q"], np.float32) * np.asarray(
            p["rom"]["w_scale"], np.float32)
        ref = np.ones((2, 24), np.float32) @ w_deq.T
        np.testing.assert_allclose(np.asarray(dx), ref, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("impl", ["int8_native", "dequant"])
    def test_trunk_impls_agree(self, impl):
        import dataclasses as dc
        spec = dc.replace(SPEC, trunk_impl=impl)
        p = rebranch.init_linear(jax.random.PRNGKey(0), 64, 32, spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
        y = rebranch.apply_linear(p, x, spec)
        ref = rebranch.apply_linear(p, x, SPEC)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=0.02, atol=0.02)

    def test_freeze_to_rom_preserves_function(self):
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (32, 16)) / np.sqrt(32)
        dense = {"layer": {"sram": {"w": w}}}
        frozen = rebranch.freeze_to_rom(dense, jax.random.PRNGKey(1), SPEC)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 32))
        y0 = x @ w
        y1 = rebranch.apply_linear(frozen["layer"], x, SPEC)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                                   rtol=0.05, atol=0.05)

    @settings(max_examples=15, deadline=None)
    @given(d_in=st.integers(8, 96), d_out=st.integers(8, 96),
           batch=st.integers(1, 5))
    def test_property_shapes_and_finite(self, d_in, d_out, batch):
        p = rebranch.init_linear(jax.random.PRNGKey(d_in * d_out), d_in,
                                 d_out, SPEC)
        x = jax.random.normal(jax.random.PRNGKey(batch), (batch, d_in))
        y = rebranch.apply_linear(p, x, SPEC)
        assert y.shape == (batch, d_out)
        assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# ROM image
# ---------------------------------------------------------------------------

class TestRom:
    def test_fingerprint_stable_and_sensitive(self):
        p = rebranch.init_linear(jax.random.PRNGKey(0), 32, 16, SPEC)
        f1 = rom.rom_fingerprint(p)
        f2 = rom.rom_fingerprint(p)
        assert f1 == f2
        p2 = jax.tree.map(lambda x: x, p)
        p2["rom"]["w_q"] = p2["rom"]["w_q"].at[0, 0].add(1)
        assert rom.rom_fingerprint(p2) != f1

    def test_fingerprint_ignores_sram(self):
        p = rebranch.init_linear(jax.random.PRNGKey(0), 32, 16, SPEC)
        f1 = rom.rom_fingerprint(p)
        p["sram"]["core"] = p["sram"]["core"] + 1.0
        assert rom.rom_fingerprint(p) == f1

    def test_rom_dominates_bytes(self):
        """paper: >90% of parameters live in ROM."""
        p = rebranch.init_linear(jax.random.PRNGKey(0), 512, 512, SPEC)
        assert rom.rom_bytes(p) > 9 * rom.sram_bytes(p)
