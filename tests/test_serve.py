"""Serving runtime: registry, slot pool, continuous-batching scheduler.

The load-bearing invariants (ISSUE 7):
  * batch occupancy never exceeds the pool size;
  * admission is FIFO and no request starves — every submitted request
    finishes within a bounded number of scheduler ticks;
  * each request's serve output is BIT-identical to a solo
    prefill+decode_step run of the same prompt (continuous batching
    changes scheduling, never results);
  * cache/batch geometry mismatches fail at the CompiledModel surface
    with a message naming both shapes, not deep inside XLA.
"""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, deploy, serve
from repro.models import api, cnn
from repro.serve.pool import SlotPool, cache_bytes_per_slot
from repro.serve.scheduler import ContinuousBatcher

MODEL_ID = "gemma-2b-smoke"
MAX_LEN = 48


@pytest.fixture(scope="module")
def cell():
    model, plan = serve.compile_entry(MODEL_ID)
    params = model.init(jax.random.PRNGKey(0))
    return model, plan, params


def _prompts(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    # varied lengths: exercises per-row cache state under batching
    return [rng.integers(0, vocab, size=6 + (i % 4)) for i in range(n)]


def _solo_decode(model, params, prompt, n_new):
    """The reference path: batch=1 prefill + decode loop."""
    cache = model.init_cache(1, MAX_LEN, dtype=jnp.float32)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(np.asarray(prompt)[None])}, cache)
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    for _ in range(n_new - 1):
        logits, cache = jax.jit(model.decode_step)(
            params, jnp.asarray([[tok]], jnp.int32), cache)
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_unknown_id_raises_with_registered_set(self):
        with pytest.raises(KeyError, match="gemma-2b-smoke"):
            serve.resolve("no-such-model")

    def test_compile_is_resident(self):
        m1, p1 = serve.compile_entry(MODEL_ID)
        m2, p2 = serve.compile_entry(MODEL_ID)
        assert m1 is m2                     # one cell per id per process

    def test_duplicate_register_needs_override(self):
        entry = serve.resolve(MODEL_ID)
        with pytest.raises(ValueError, match="already registered"):
            serve.register(entry)
        serve.register(entry, override=True)   # idempotent with override

    def test_builtin_zoo_covers_lms_and_cnns(self):
        ids = serve.registered_ids()
        assert "gemma-2b-smoke" in ids and "falcon-mamba-7b-smoke" in ids
        assert "darknet19-32" in ids and "vgg8-32" in ids

    def test_lm_entries_carry_a_plan(self, cell):
        _, plan, _ = cell
        assert plan is not None and plan.model == "gemma_2b_smoke"

    def test_reregister_drops_resident_cell(self):
        serve.register(serve.ModelEntry(
            "rereg-test",
            config=lambda: cnn.CNNConfig(name="vgg8", input_size=16)),
            override=True)
        m1, _ = serve.compile_entry("rereg-test")
        assert m1.cfg.input_size == 16
        serve.register(serve.ModelEntry(
            "rereg-test",
            config=lambda: cnn.CNNConfig(name="vgg8", input_size=32)),
            override=True)
        m2, _ = serve.compile_entry("rereg-test")
        assert m2 is not m1 and m2.cfg.input_size == 32

    def test_compile_racing_reregister_never_publishes_stale_cell(self):
        """A re-register landing mid-compile must not let the in-flight
        compile publish the OLD entry's cell (it would silently serve a
        stale config).  The entry's config factory runs inside
        compile_entry, which lets the race be staged deterministically:
        the old factory re-registers the id before returning."""
        def old_factory():
            serve.register(serve.ModelEntry(
                "race-test",
                config=lambda: cnn.CNNConfig(name="vgg8", input_size=32)),
                override=True)
            return cnn.CNNConfig(name="vgg8", input_size=16)

        serve.register(serve.ModelEntry("race-test", config=old_factory),
                       override=True)
        model, _ = serve.compile_entry("race-test")
        assert model.cfg.input_size == 32    # stale 16px cell discarded


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------

class TestSlotPool:
    def test_alloc_release_cycle(self, cell):
        model, _, _ = cell
        pool = SlotPool(model, 3, MAX_LEN)
        slots = [pool.alloc() for _ in range(3)]
        assert sorted(slots) == [0, 1, 2]
        assert pool.alloc() is None and pool.occupancy == 3
        pool.release(slots[1])
        assert pool.free_slots == 1 and pool.alloc() == slots[1]

    def test_double_release_raises(self, cell):
        model, _, _ = cell
        pool = SlotPool(model, 2, MAX_LEN)
        s = pool.alloc()
        pool.release(s)
        with pytest.raises(ValueError, match="double-released"):
            pool.release(s)

    def test_adopt_copies_the_row_bitwise(self, cell):
        model, _, params = cell
        pool = SlotPool(model, 3, MAX_LEN)
        prompt = _prompts(1, model.cfg.vocab_size)[0]
        solo = pool.solo_cache()
        _, solo = jax.jit(model.prefill)(
            params, {"tokens": jnp.asarray(prompt[None])}, solo)
        pool.adopt(1, solo)
        axis = 1 if model.cfg.scan_layers else 0
        for pl, sl in zip(jax.tree.leaves(pool.cache),
                          jax.tree.leaves(solo)):
            row = jnp.take(pl, 1, axis=axis)
            np.testing.assert_array_equal(
                np.asarray(row),
                np.asarray(jnp.take(sl, 0, axis=axis)))

    def test_suggest_slots_respects_budget(self, cell):
        model, plan, _ = cell
        per_slot = cache_bytes_per_slot(model, MAX_LEN)
        assert per_slot > 0
        tiny = serve.suggest_slots(model, plan, MAX_LEN,
                                   sram_capacity_bytes=0)
        assert tiny == 1                     # never a zero-slot pool
        big = serve.suggest_slots(model, plan, MAX_LEN,
                                  sram_capacity_bytes=1 << 40)
        assert big == 64                     # capped
        mid = serve.suggest_slots(model, plan, MAX_LEN,
                                  sram_capacity_bytes=per_slot * 5)
        assert 1 <= mid <= 5


# ---------------------------------------------------------------------------
# continuous batching scheduler
# ---------------------------------------------------------------------------

class TestScheduler:
    def _served(self, cell, n_req, n_slots, gens=None, track=None):
        model, _, params = cell
        pool = SlotPool(model, n_slots, MAX_LEN)
        b = ContinuousBatcher(model, params, pool)
        prompts = _prompts(n_req, model.cfg.vocab_size)
        gens = gens or [5] * n_req
        reqs = [b.submit(p, g) for p, g in zip(prompts, gens)]
        while not b.idle:
            b.step()
            if track is not None:
                track(b)
            assert b.step_count < 500, "scheduler stuck"
        return b, reqs, prompts

    def test_occupancy_never_exceeds_pool(self, cell):
        peaks = []
        b, reqs, _ = self._served(
            cell, n_req=6, n_slots=2,
            track=lambda b: peaks.append(b.active))
        assert max(peaks) <= 2
        assert all(r.done for r in reqs)

    def test_no_starvation_fifo(self, cell):
        """With a pool of 2 and 6 equal requests, admission must proceed
        in submit order and every request must finish within the bound
        of ceil(n/slots) generations."""
        b, reqs, _ = self._served(cell, n_req=6, n_slots=2)
        admits = [r.admit_step for r in reqs]
        assert admits == sorted(admits)          # FIFO admission
        for r in reqs:
            assert r.done
            # waited at most ceil(6/2)=3 generation rounds of 5 tokens
            assert r.finish_step - r.submit_step <= 3 * 5

    def test_bit_identical_to_solo(self, cell):
        """The headline invariant: continuous batching (varied prompt
        lengths, staggered joins, mid-batch retirement) returns exactly
        the solo path's tokens for every request."""
        model, _, params = cell
        # heterogeneous gen lengths force mid-batch retire + late joins
        gens = [4, 7, 3, 6, 5]
        b, reqs, prompts = self._served(cell, n_req=5, n_slots=2,
                                        gens=gens)
        for r, p, g in zip(reqs, prompts, gens):
            assert r.tokens == _solo_decode(model, params, p, g), \
                f"request {r.rid} diverged from solo decode"

    def test_late_submission_joins_running_batch(self, cell):
        model, _, params = cell
        pool = SlotPool(model, 2, MAX_LEN)
        b = ContinuousBatcher(model, params, pool)
        prompts = _prompts(2, model.cfg.vocab_size)
        r1 = b.submit(prompts[0], 8)
        for _ in range(3):
            b.step()
        r2 = b.submit(prompts[1], 4)         # joins at a step boundary
        b.drain(max_steps=100)
        assert r2.admit_step > r1.admit_step
        assert r1.tokens == _solo_decode(model, params, prompts[0], 8)
        assert r2.tokens == _solo_decode(model, params, prompts[1], 4)

    def test_eos_retires_early(self, cell):
        model, _, params = cell
        prompt = _prompts(1, model.cfg.vocab_size)[0]
        ref = _solo_decode(model, params, prompt, 8)
        eos = ref[2]                          # hit no later than token 3
        pool = SlotPool(model, 2, MAX_LEN)
        b = ContinuousBatcher(model, params, pool)
        r = b.submit(prompt, 8, eos_id=eos)
        b.drain(max_steps=100)
        # retire at the FIRST occurrence (eos may repeat earlier in ref)
        assert r.tokens == ref[:ref.index(eos) + 1]
        assert len(r.tokens) < 8
        assert pool.occupancy == 0            # slot returned

    def test_submit_validation(self, cell):
        model, _, params = cell
        b = ContinuousBatcher(model, params, SlotPool(model, 1, MAX_LEN))
        with pytest.raises(ValueError, match="empty prompt"):
            b.submit([], 4)
        with pytest.raises(ValueError, match="max_new_tokens"):
            b.submit([1, 2], 0)
        with pytest.raises(ValueError, match="max_len"):
            b.submit(list(range(40)), 20)     # 60 > MAX_LEN


# ---------------------------------------------------------------------------
# front door (async LM + forward-only CNN)
# ---------------------------------------------------------------------------

class TestFrontDoor:
    def test_async_generate_batches_concurrent_callers(self, cell):
        model, _, params = cell
        srv = serve.LMServer(model, params, n_slots=4, max_len=MAX_LEN)
        prompts = _prompts(3, model.cfg.vocab_size, seed=7)

        async def main():
            return await asyncio.gather(
                *[srv.generate(p, 5) for p in prompts])

        outs = asyncio.run(main())
        for p, got in zip(prompts, outs):
            assert got == _solo_decode(model, params, p, 5)

    def test_cnn_front_door_matches_solo_forward(self):
        srv = serve.load("vgg8-32", n_slots=4, key=jax.random.PRNGKey(1))
        assert isinstance(srv, serve.CNNServer)
        rng = np.random.default_rng(3)
        imgs = rng.normal(size=(6, 32, 32, 3)).astype(np.float32)
        got = srv.submit(imgs)
        assert got.shape == (6, srv.model.cfg.num_classes)
        # chunking + padding must be INVISIBLE: rows equal the same
        # images run through the same fixed-geometry forward, bitwise
        pad = jnp.concatenate(
            [jnp.asarray(imgs[4:]), jnp.zeros((2, 32, 32, 3))], 0)
        ref = np.concatenate([
            np.asarray(srv._forward(srv.params, jnp.asarray(imgs[:4]))),
            np.asarray(srv._forward(srv.params, pad))[:2]], 0)
        np.testing.assert_array_equal(got, ref)
        for i in range(6):   # and close to the solo batch=1 forward
            solo = np.asarray(srv.model.forward(
                srv.params, jnp.asarray(imgs[i:i + 1])))
            np.testing.assert_allclose(got[i], solo[0], rtol=2e-3,
                                       atol=2e-3)

    def test_load_lm_sizes_pool_from_plan(self):
        srv = serve.load(MODEL_ID, max_len=MAX_LEN)
        assert isinstance(srv, serve.LMServer)
        assert 1 <= srv.pool.n_slots <= 64


# ---------------------------------------------------------------------------
# cache/batch geometry validation at the CompiledModel surface
# ---------------------------------------------------------------------------

class TestCacheGeometry:
    @pytest.fixture(scope="class")
    def lm(self):
        cfg = configs.get_smoke("gemma_2b")
        model = deploy.compile_model(cfg)
        return model, model.init(jax.random.PRNGKey(0))

    def test_prefill_batch_mismatch_names_both_shapes(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        with pytest.raises(ValueError,
                           match=r"batch=2.*batch=4") as e:
            model.prefill(params, {"tokens": jnp.zeros((4, 8), jnp.int32)},
                          cache)
        assert "init_cache" in str(e.value)

    def test_decode_batch_mismatch(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        with pytest.raises(ValueError, match=r"batch=2.*batch=3"):
            model.decode_step(params, jnp.zeros((3, 1), jnp.int32), cache)

    def test_decode_multi_token_rejected(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        with pytest.raises(ValueError, match="ONE token"):
            model.decode_step(params, jnp.zeros((2, 4), jnp.int32), cache)

    def test_prompt_longer_than_horizon(self, lm):
        model, params = lm
        cache = model.init_cache(2, 16, dtype=jnp.float32)
        with pytest.raises(ValueError, match="horizon"):
            model.prefill(params,
                          {"tokens": jnp.zeros((2, 20), jnp.int32)}, cache)

    def test_raises_under_jit_too(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        with pytest.raises(ValueError, match="batch"):
            jax.jit(model.decode_step)(
                params, jnp.zeros((5, 1), jnp.int32), cache)

    def test_geometry_helper_all_families(self):
        for arch, horizon_none in [("falcon_mamba_7b", True),
                                   ("hymba_1_5b", False),
                                   ("qwen2_moe_a2_7b", False)]:
            cfg = configs.get_smoke(arch)
            cache = api.init_cache(cfg, 3, 16, jnp.float32)
            batch, horizon = api.cache_geometry(cfg, cache)
            assert batch == 3
            assert (horizon is None) == horizon_none
            if horizon is not None:
                assert horizon == 16

    def test_valid_geometry_passes(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        logits, cache = model.prefill(
            params, {"tokens": jnp.zeros((2, 8), jnp.int32)}, cache)
        logits, _ = model.decode_step(
            params, jnp.zeros((2, 1), jnp.int32), cache)
        assert logits.shape[0] == 2


# ---------------------------------------------------------------------------
# the per-row ring-slot decode fix (serve-path bug)
# ---------------------------------------------------------------------------

class TestPerRowCacheRows:
    def test_mixed_length_rows_decode_independently(self, cell):
        """Rows at different lengths in ONE cache must each write their
        own ring slot: before the fix, every row wrote row 0's slot,
        corrupting any batch whose lengths diverged (exactly the
        continuous-batching state)."""
        model, _, params = cell
        prompts = _prompts(3, model.cfg.vocab_size, seed=11)  # 6,7,8 long
        solo_caches = []
        toks = []
        for p in prompts:
            c = model.init_cache(1, MAX_LEN, dtype=jnp.float32)
            lg, c = jax.jit(model.prefill)(
                params, {"tokens": jnp.asarray(p[None])}, c)
            solo_caches.append(c)
            toks.append(int(jnp.argmax(lg[0, -1])))
        pool = SlotPool(model, 3, MAX_LEN)
        for i, c in enumerate(solo_caches):
            pool.adopt(i, c)
        tok = jnp.asarray(np.asarray(toks, np.int32)[:, None])
        batched_logits, _ = jax.jit(model.decode_step)(
            params, tok, pool.cache)
        for i in range(3):
            solo_logits, _ = jax.jit(model.decode_step)(
                params, tok[i:i + 1], solo_caches[i])
            np.testing.assert_array_equal(
                np.asarray(batched_logits[i]), np.asarray(solo_logits[0]),
                err_msg=f"row {i} (len {prompts[i].size}) diverged")
