"""Serving runtime: registry, KV pools, continuous-batching scheduler.

The load-bearing invariants (ISSUE 7 + ISSUE 9):
  * batch occupancy never exceeds the pool size;
  * admission is FIFO and no request starves — every submitted request
    finishes within a bounded number of scheduler ticks;
  * each request's serve output is BIT-identical to a solo
    prefill+decode_step run of the same prompt (continuous batching
    changes scheduling, never results) — over the dense SlotPool, over
    the paged block pool, and with prefill split into chunks;
  * paged admission is conservative: a request admits only when its
    whole reservation fits, so decode can never deadlock on blocks;
  * cache/batch geometry mismatches fail at the CompiledModel surface
    with a message naming both shapes, not deep inside XLA.
"""

import asyncio
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, deploy, serve
from repro.core import rebranch
from repro.models import api, cnn
from repro.serve.pool import (PagedPool, SlotPool, cache_bytes_per_slot,
                              suggest_paged)
from repro.serve.scheduler import ContinuousBatcher

MODEL_ID = "gemma-2b-smoke"
MAX_LEN = 48


@pytest.fixture(scope="module")
def cell():
    model, plan = serve.compile_entry(MODEL_ID)
    params = model.init(jax.random.PRNGKey(0))
    return model, plan, params


def _prompts(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    # varied lengths: exercises per-row cache state under batching
    return [rng.integers(0, vocab, size=6 + (i % 4)) for i in range(n)]


def _solo_decode(model, params, prompt, n_new):
    """The reference path: batch=1 prefill + decode loop."""
    cache = model.init_cache(1, MAX_LEN, dtype=jnp.float32)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(np.asarray(prompt)[None])}, cache)
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    for _ in range(n_new - 1):
        logits, cache = jax.jit(model.decode_step)(
            params, jnp.asarray([[tok]], jnp.int32), cache)
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_unknown_id_raises_with_registered_set(self):
        with pytest.raises(KeyError, match="gemma-2b-smoke"):
            serve.resolve("no-such-model")

    def test_compile_is_resident(self):
        m1, p1 = serve.compile_entry(MODEL_ID)
        m2, p2 = serve.compile_entry(MODEL_ID)
        assert m1 is m2                     # one cell per id per process

    def test_duplicate_register_needs_override(self):
        entry = serve.resolve(MODEL_ID)
        with pytest.raises(ValueError, match="already registered"):
            serve.register(entry)
        serve.register(entry, override=True)   # idempotent with override

    def test_builtin_zoo_covers_lms_and_cnns(self):
        ids = serve.registered_ids()
        assert "gemma-2b-smoke" in ids and "falcon-mamba-7b-smoke" in ids
        assert "darknet19-32" in ids and "vgg8-32" in ids

    def test_lm_entries_carry_a_plan(self, cell):
        _, plan, _ = cell
        assert plan is not None and plan.model == "gemma_2b_smoke"

    def test_reregister_drops_resident_cell(self):
        serve.register(serve.ModelEntry(
            "rereg-test",
            config=lambda: cnn.CNNConfig(name="vgg8", input_size=16)),
            override=True)
        m1, _ = serve.compile_entry("rereg-test")
        assert m1.cfg.input_size == 16
        serve.register(serve.ModelEntry(
            "rereg-test",
            config=lambda: cnn.CNNConfig(name="vgg8", input_size=32)),
            override=True)
        m2, _ = serve.compile_entry("rereg-test")
        assert m2 is not m1 and m2.cfg.input_size == 32

    def test_compile_racing_reregister_never_publishes_stale_cell(self):
        """A re-register landing mid-compile must not let the in-flight
        compile publish the OLD entry's cell (it would silently serve a
        stale config).  The entry's config factory runs inside
        compile_entry, which lets the race be staged deterministically:
        the old factory re-registers the id before returning."""
        def old_factory():
            serve.register(serve.ModelEntry(
                "race-test",
                config=lambda: cnn.CNNConfig(name="vgg8", input_size=32)),
                override=True)
            return cnn.CNNConfig(name="vgg8", input_size=16)

        serve.register(serve.ModelEntry("race-test", config=old_factory),
                       override=True)
        model, _ = serve.compile_entry("race-test")
        assert model.cfg.input_size == 32    # stale 16px cell discarded


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------

class TestSlotPool:
    def test_alloc_release_cycle(self, cell):
        model, _, _ = cell
        pool = SlotPool(model, 3, MAX_LEN)
        slots = [pool.alloc() for _ in range(3)]
        assert sorted(slots) == [0, 1, 2]
        assert pool.alloc() is None and pool.occupancy == 3
        pool.release(slots[1])
        assert pool.free_slots == 1 and pool.alloc() == slots[1]

    def test_double_release_raises(self, cell):
        model, _, _ = cell
        pool = SlotPool(model, 2, MAX_LEN)
        s = pool.alloc()
        pool.release(s)
        with pytest.raises(ValueError, match="double-released"):
            pool.release(s)

    def test_adopt_copies_the_row_bitwise(self, cell):
        model, _, params = cell
        pool = SlotPool(model, 3, MAX_LEN)
        prompt = _prompts(1, model.cfg.vocab_size)[0]
        solo = pool.solo_cache()
        _, solo = jax.jit(model.prefill)(
            params, {"tokens": jnp.asarray(prompt[None])}, solo)
        pool.adopt(1, solo)
        axis = 1 if model.cfg.scan_layers else 0
        for pl, sl in zip(jax.tree.leaves(pool.cache),
                          jax.tree.leaves(solo)):
            row = jnp.take(pl, 1, axis=axis)
            np.testing.assert_array_equal(
                np.asarray(row),
                np.asarray(jnp.take(sl, 0, axis=axis)))

    def test_suggest_slots_respects_budget(self, cell):
        model, plan, _ = cell
        per_slot = cache_bytes_per_slot(model, MAX_LEN)
        assert per_slot > 0
        tiny = serve.suggest_slots(model, plan, MAX_LEN,
                                   sram_capacity_bytes=0)
        assert tiny == 1                     # never a zero-slot pool
        big = serve.suggest_slots(model, plan, MAX_LEN,
                                  sram_capacity_bytes=1 << 40)
        assert big == 64                     # capped
        mid = serve.suggest_slots(model, plan, MAX_LEN,
                                  sram_capacity_bytes=per_slot * 5)
        assert 1 <= mid <= 5


# ---------------------------------------------------------------------------
# continuous batching scheduler
# ---------------------------------------------------------------------------

class TestScheduler:
    def _served(self, cell, n_req, n_slots, gens=None, track=None):
        model, _, params = cell
        pool = SlotPool(model, n_slots, MAX_LEN)
        b = ContinuousBatcher(model, params, pool)
        prompts = _prompts(n_req, model.cfg.vocab_size)
        gens = gens or [5] * n_req
        reqs = [b.submit(p, g) for p, g in zip(prompts, gens)]
        while not b.idle:
            b.step()
            if track is not None:
                track(b)
            assert b.step_count < 500, "scheduler stuck"
        return b, reqs, prompts

    def test_occupancy_never_exceeds_pool(self, cell):
        peaks = []
        b, reqs, _ = self._served(
            cell, n_req=6, n_slots=2,
            track=lambda b: peaks.append(b.active))
        assert max(peaks) <= 2
        assert all(r.done for r in reqs)

    def test_no_starvation_fifo(self, cell):
        """With a pool of 2 and 6 equal requests, admission must proceed
        in submit order and every request must finish within the bound
        of ceil(n/slots) generations."""
        b, reqs, _ = self._served(cell, n_req=6, n_slots=2)
        admits = [r.admit_step for r in reqs]
        assert admits == sorted(admits)          # FIFO admission
        for r in reqs:
            assert r.done
            # waited at most ceil(6/2)=3 generation rounds of 5 tokens
            assert r.finish_step - r.submit_step <= 3 * 5

    def test_bit_identical_to_solo(self, cell):
        """The headline invariant: continuous batching (varied prompt
        lengths, staggered joins, mid-batch retirement) returns exactly
        the solo path's tokens for every request."""
        model, _, params = cell
        # heterogeneous gen lengths force mid-batch retire + late joins
        gens = [4, 7, 3, 6, 5]
        b, reqs, prompts = self._served(cell, n_req=5, n_slots=2,
                                        gens=gens)
        for r, p, g in zip(reqs, prompts, gens):
            assert r.tokens == _solo_decode(model, params, p, g), \
                f"request {r.rid} diverged from solo decode"

    def test_late_submission_joins_running_batch(self, cell):
        model, _, params = cell
        pool = SlotPool(model, 2, MAX_LEN)
        b = ContinuousBatcher(model, params, pool)
        prompts = _prompts(2, model.cfg.vocab_size)
        r1 = b.submit(prompts[0], 8)
        for _ in range(3):
            b.step()
        r2 = b.submit(prompts[1], 4)         # joins at a step boundary
        b.drain(max_steps=100)
        assert r2.admit_step > r1.admit_step
        assert r1.tokens == _solo_decode(model, params, prompts[0], 8)
        assert r2.tokens == _solo_decode(model, params, prompts[1], 4)

    def test_eos_retires_early(self, cell):
        model, _, params = cell
        prompt = _prompts(1, model.cfg.vocab_size)[0]
        ref = _solo_decode(model, params, prompt, 8)
        eos = ref[2]                          # hit no later than token 3
        pool = SlotPool(model, 2, MAX_LEN)
        b = ContinuousBatcher(model, params, pool)
        r = b.submit(prompt, 8, eos_id=eos)
        b.drain(max_steps=100)
        # retire at the FIRST occurrence (eos may repeat earlier in ref)
        assert r.tokens == ref[:ref.index(eos) + 1]
        assert len(r.tokens) < 8
        assert pool.occupancy == 0            # slot returned

    def test_submit_validation(self, cell):
        model, _, params = cell
        b = ContinuousBatcher(model, params, SlotPool(model, 1, MAX_LEN))
        with pytest.raises(ValueError, match="empty prompt"):
            b.submit([], 4)
        with pytest.raises(ValueError, match="max_new_tokens"):
            b.submit([1, 2], 0)
        with pytest.raises(ValueError, match="max_len"):
            b.submit(list(range(40)), 20)     # 60 > MAX_LEN


# ---------------------------------------------------------------------------
# front door (async LM + forward-only CNN)
# ---------------------------------------------------------------------------

class TestFrontDoor:
    def test_async_generate_batches_concurrent_callers(self, cell):
        model, _, params = cell
        srv = serve.LMServer(model, params, n_slots=4, max_len=MAX_LEN)
        prompts = _prompts(3, model.cfg.vocab_size, seed=7)

        async def main():
            return await asyncio.gather(
                *[srv.generate(p, 5) for p in prompts])

        outs = asyncio.run(main())
        for p, got in zip(prompts, outs):
            assert got == _solo_decode(model, params, p, 5)

    def test_cnn_front_door_matches_solo_forward(self):
        srv = serve.load("vgg8-32", n_slots=4, key=jax.random.PRNGKey(1))
        assert isinstance(srv, serve.CNNServer)
        rng = np.random.default_rng(3)
        imgs = rng.normal(size=(6, 32, 32, 3)).astype(np.float32)
        got = srv.submit(imgs)
        assert got.shape == (6, srv.model.cfg.num_classes)
        # chunking + padding must be INVISIBLE: rows equal the same
        # images run through the same fixed-geometry forward, bitwise
        pad = jnp.concatenate(
            [jnp.asarray(imgs[4:]), jnp.zeros((2, 32, 32, 3))], 0)
        ref = np.concatenate([
            np.asarray(srv._forward(srv.params, jnp.asarray(imgs[:4]))),
            np.asarray(srv._forward(srv.params, pad))[:2]], 0)
        np.testing.assert_array_equal(got, ref)
        for i in range(6):   # and close to the solo batch=1 forward
            solo = np.asarray(srv.model.forward(
                srv.params, jnp.asarray(imgs[i:i + 1])))
            np.testing.assert_allclose(got[i], solo[0], rtol=2e-3,
                                       atol=2e-3)

    def test_load_lm_sizes_pool_from_plan(self):
        srv = serve.load(MODEL_ID, max_len=MAX_LEN)
        assert isinstance(srv, serve.LMServer)
        assert 1 <= srv.pool.n_slots <= 64


# ---------------------------------------------------------------------------
# cache/batch geometry validation at the CompiledModel surface
# ---------------------------------------------------------------------------

class TestCacheGeometry:
    @pytest.fixture(scope="class")
    def lm(self):
        cfg = configs.get_smoke("gemma_2b")
        model = deploy.compile_model(cfg)
        return model, model.init(jax.random.PRNGKey(0))

    def test_prefill_batch_mismatch_names_both_shapes(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        with pytest.raises(ValueError,
                           match=r"batch=2.*batch=4") as e:
            model.prefill(params, {"tokens": jnp.zeros((4, 8), jnp.int32)},
                          cache)
        assert "init_cache" in str(e.value)

    def test_decode_batch_mismatch(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        with pytest.raises(ValueError, match=r"batch=2.*batch=3"):
            model.decode_step(params, jnp.zeros((3, 1), jnp.int32), cache)

    def test_decode_multi_token_rejected(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        with pytest.raises(ValueError, match="ONE token"):
            model.decode_step(params, jnp.zeros((2, 4), jnp.int32), cache)

    def test_prompt_longer_than_horizon(self, lm):
        model, params = lm
        cache = model.init_cache(2, 16, dtype=jnp.float32)
        with pytest.raises(ValueError, match="horizon"):
            model.prefill(params,
                          {"tokens": jnp.zeros((2, 20), jnp.int32)}, cache)

    def test_raises_under_jit_too(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        with pytest.raises(ValueError, match="batch"):
            jax.jit(model.decode_step)(
                params, jnp.zeros((5, 1), jnp.int32), cache)

    def test_geometry_helper_all_families(self):
        for arch, horizon_none in [("falcon_mamba_7b", True),
                                   ("hymba_1_5b", False),
                                   ("qwen2_moe_a2_7b", False)]:
            cfg = configs.get_smoke(arch)
            cache = api.init_cache(cfg, 3, 16, jnp.float32)
            batch, horizon = api.cache_geometry(cfg, cache)
            assert batch == 3
            assert (horizon is None) == horizon_none
            if horizon is not None:
                assert horizon == 16

    def test_valid_geometry_passes(self, lm):
        model, params = lm
        cache = model.init_cache(2, 32, dtype=jnp.float32)
        logits, cache = model.prefill(
            params, {"tokens": jnp.zeros((2, 8), jnp.int32)}, cache)
        logits, _ = model.decode_step(
            params, jnp.zeros((2, 1), jnp.int32), cache)
        assert logits.shape[0] == 2


# ---------------------------------------------------------------------------
# paged KV pool (ISSUE 9 tentpole)
# ---------------------------------------------------------------------------

class TestPagedPool:
    BS = 8          # block size; MAX_LEN=48 -> 6 logical blocks per row

    def _pool(self, model, rows=3, blocks=12):
        return PagedPool(model, rows, blocks, self.BS, MAX_LEN)

    def test_admit_reserves_conservatively(self, cell):
        """Admission must refuse unless the WHOLE request (prompt +
        max_new) is guaranteed blocks — over-admitting would deadlock
        decode mid-request on an empty free list."""
        model, _, _ = cell
        pool = self._pool(model, rows=3, blocks=7)
        r1 = pool.try_admit(MAX_LEN)          # reserves 6 of 7 blocks
        assert r1 is not None
        assert pool.try_admit(2 * self.BS) is None   # 2 > 7-6 remaining
        assert pool.try_admit(self.BS) is not None   # exactly fits
        pool.release(r1)
        assert pool.try_admit(2 * self.BS) is not None

    def test_rows_and_blocks_both_gate_admission(self, cell):
        model, _, _ = cell
        pool = self._pool(model, rows=1, blocks=12)
        assert pool.try_admit(8) is not None
        assert pool.try_admit(8) is None      # blocks free, rows gone
        with pytest.raises(ValueError, match="max_len"):
            pool.try_admit(MAX_LEN + 1)       # could never fit

    def test_release_returns_blocks_and_row(self, cell):
        model, _, _ = cell
        pool = self._pool(model)
        row = pool.try_admit(20)
        pool.release(row)
        assert pool.free_slots == 3 and pool.blocks_in_use == 0
        assert pool.blocks_reserved == 0
        with pytest.raises(ValueError, match="double-released"):
            pool.release(row)

    def test_geometry_errors(self, cell):
        model, _, _ = cell
        with pytest.raises(ValueError, match="does not divide"):
            PagedPool(model, 2, 12, 7, MAX_LEN)       # 7 ∤ 48
        with pytest.raises(ValueError, match="one full-horizon"):
            PagedPool(model, 2, 3, self.BS, MAX_LEN)  # 3 < 6 blocks
        cfg = configs.get_smoke("falcon_mamba_7b")
        assert not api.supports_paging(cfg)
        with pytest.raises(ValueError, match="paged"):
            api.init_paged_cache(cfg, 2, 8, 8, 32)

    def test_adopt_scatters_the_row_bitwise(self, cell):
        """The gathered logical view of an adopted row must equal the
        dense solo cache at every valid position — paging moves bytes,
        never bits."""
        from repro.models.layers import _gather_paged
        model, _, params = cell
        pool = self._pool(model)
        prompt = _prompts(1, model.cfg.vocab_size)[0]
        solo = pool.solo_cache()
        _, solo = jax.jit(model.prefill)(
            params, {"tokens": jnp.asarray(prompt[None])}, solo)
        row = pool.try_admit(prompt.size + 4)
        pool.adopt(row, solo)
        axis = 1 if model.cfg.scan_layers else 0
        length = int(np.asarray(
            api._first_layer(solo)["length"]).reshape(-1)[0])
        first = api._first_layer(pool.cache)
        k_phys = jnp.take(first["k"], 0, axis=0) if axis else first["k"]
        table = jnp.take(first["table"], 0, axis=0) if axis \
            else first["table"]
        view = _gather_paged(k_phys, table)[row]
        solo_k = api._first_layer(solo)["k"]
        solo_row = jnp.take(solo_k, 0, axis=0)[0] if axis \
            else solo_k[0]
        np.testing.assert_array_equal(np.asarray(view[:length]),
                                      np.asarray(solo_row[:length]))

    def test_suggest_paged_matches_dense_budget(self, cell):
        model, plan, _ = cell
        rows, blocks, bs = suggest_paged(model, plan, MAX_LEN,
                                         sram_capacity_bytes=1 << 30)
        assert MAX_LEN % bs == 0
        assert blocks * bs >= MAX_LEN          # at least one full request
        assert 1 <= rows <= 64


class TestPagedScheduler:
    def _batcher(self, cell, rows=4, blocks=18, bs=8, chunk=None):
        model, _, params = cell
        pool = PagedPool(model, rows, blocks, bs, MAX_LEN)
        return pool, ContinuousBatcher(model, params, pool,
                                       prefill_chunk=chunk)

    def test_bit_identical_to_solo_over_paged_pool(self, cell):
        """The headline invariant survives paging: mixed prompt
        lengths, staggered joins, mid-batch retirement through block
        tables return exactly the solo path's tokens."""
        model, _, params = cell
        pool, b = self._batcher(cell)
        prompts = _prompts(5, model.cfg.vocab_size)
        gens = [4, 7, 3, 6, 5]
        reqs = [b.submit(p, g) for p, g in zip(prompts, gens)]
        b.drain(max_steps=200)
        for r, p, g in zip(reqs, prompts, gens):
            assert r.tokens == _solo_decode(model, params, p, g), \
                f"request {r.rid} (len {p.size}) diverged over paging"
        assert pool.blocks_in_use == 0 and pool.occupancy == 0

    def test_blocks_grow_on_demand(self, cell):
        """Adoption grants only the prompt's blocks; decode growth
        grants the rest one block at a time (early EOS never
        materialises the reservation's tail)."""
        model, _, params = cell
        pool, b = self._batcher(cell, rows=2, blocks=12, bs=4)
        b.submit(_prompts(1, model.cfg.vocab_size)[0], 10)  # 6-token prompt
        b.step()                      # admitted: 2 blocks cover prompt+1
        start = pool.blocks_in_use
        assert start <= 2
        high = start
        while not b.idle:
            b.step()
            high = max(high, pool.blocks_in_use)
        assert high > start           # grew during decode
        assert pool.blocks_in_use == 0

    def test_admission_waits_for_blocks_not_just_rows(self, cell):
        """With rows to spare but blocks exhausted, later requests must
        queue (FIFO, work-conserving) and admit once blocks free."""
        model, _, params = cell
        pool, b = self._batcher(cell, rows=4, blocks=6, bs=8)
        prompts = _prompts(3, model.cfg.vocab_size)
        r1 = b.submit(prompts[0], MAX_LEN - prompts[0].size)  # all 6 blocks
        r2 = b.submit(prompts[1], 4)
        b.step()
        assert r1.admit_step >= 0 and r2.admit_step < 0
        assert pool.free_slots == 3          # rows were never the limit
        b.drain(max_steps=200)
        assert r2.done
        assert r2.admit_step > r1.admit_step


# ---------------------------------------------------------------------------
# chunked prefill admission (ISSUE 9 tentpole)
# ---------------------------------------------------------------------------

class TestChunkedPrefill:
    def test_chunked_prefill_bit_identical(self, cell):
        """A prompt prefilled in chunks across scheduler ticks must
        adopt a row bit-identical to the whole-prompt solo prefill —
        every chunk extends the same cache at absolute positions."""
        model, _, params = cell
        for pool in (SlotPool(model, 2, MAX_LEN),
                     PagedPool(model, 2, 14, 8, MAX_LEN)):
            b = ContinuousBatcher(model, params, pool, prefill_chunk=4)
            prompts = _prompts(3, model.cfg.vocab_size, seed=3)
            reqs = [b.submit(p, 5) for p in prompts]
            b.drain(max_steps=200)
            for r, p in zip(reqs, prompts):
                assert r.tokens == _solo_decode(model, params, p, 5), \
                    f"chunked prefill diverged ({type(pool).__name__})"

    def test_prefill_chunks_interleave_with_decode(self, cell):
        """Admitting a long prompt must not stall in-flight decodes:
        with chunk=2, an active request keeps gaining tokens on the
        ticks the new prompt's chunks run."""
        model, _, params = cell
        pool = SlotPool(model, 2, MAX_LEN)
        b = ContinuousBatcher(model, params, pool, prefill_chunk=2)
        prompts = _prompts(2, model.cfg.vocab_size, seed=9)
        r1 = b.submit(prompts[0], 12)
        ticks = 0
        while r1.admit_step < 0:                  # r1's own chunks run
            b.step()
            ticks += 1
            assert ticks < 20
        r2 = b.submit(prompts[1], 4)              # 7 tokens: 4 chunks
        grew = []
        while b.prefilling or r2.admit_step < 0:
            before = len(r1.tokens)
            b.step()
            grew.append(len(r1.tokens) > before)
            ticks += 1
            assert ticks < 100
        assert grew and all(grew), \
            "decode stalled during chunked prefill"
        b.drain(max_steps=100)
        assert r1.tokens == _solo_decode(model, params, prompts[0], 12)
        assert r2.tokens == _solo_decode(model, params, prompts[1], 4)

    def test_swap_barrier_waits_for_inflight_prefill(self, cell):
        """A scenario swap queued behind a chunk-prefilling request
        must not apply until that prefill (and its decode) finishes —
        chunks after the swap would run under the wrong params."""
        model, _, pA = cell
        brB = jax.tree.map(
            lambda x: x + jnp.asarray(0.02, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            rebranch.partition(pA)[0])
        pool = SlotPool(model, 2, MAX_LEN)
        b = ContinuousBatcher(model, jax.tree.map(jnp.array, pA), pool,
                              scenario="a", prefill_chunk=2)
        prompt = _prompts(1, model.cfg.vocab_size, seed=13)[0]
        r1 = b.submit(prompt, 4, scenario="a")
        b.step()                               # first chunk only
        assert b.prefilling
        b.swap("b", brB)
        b.step()
        assert b.scenario == "a"               # barrier held
        b.drain(max_steps=100)
        assert b.scenario == "b" and b.swap_count == 1
        assert r1.tokens == _solo_decode(model, pA, prompt, 4)

    def test_chunking_rejected_for_recurrent_families(self):
        cfg = configs.get_smoke("falcon_mamba_7b")
        assert not api.supports_chunked_prefill(cfg)
        model = deploy.compile_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        pool = SlotPool(model, 1, 32)
        b = ContinuousBatcher(model, params, pool)      # auto -> 0
        assert b.prefill_chunk == 0
        with pytest.raises(ValueError, match="cannot chunk"):
            ContinuousBatcher(model, params, pool, prefill_chunk=8)


# ---------------------------------------------------------------------------
# paged cache geometry at the CompiledModel surface
# ---------------------------------------------------------------------------

class TestPagedGeometry:
    def test_paged_cache_reports_logical_geometry(self, cell):
        model, _, _ = cell
        cache = model.init_paged_cache(3, 10, 8, MAX_LEN)
        batch, horizon = api.cache_geometry(model.cfg, cache)
        assert batch == 3 and horizon == MAX_LEN

    def test_prefill_on_paged_cache_names_the_adopt_path(self, cell):
        model, _, params = cell
        cache = model.init_paged_cache(2, 10, 8, MAX_LEN)
        with pytest.raises(ValueError, match="adopt"):
            model.prefill(params,
                          {"tokens": jnp.zeros((2, 8), jnp.int32)}, cache)

    def test_decode_batch_mismatch_names_block_table_rows(self, cell):
        model, _, params = cell
        cache = model.init_paged_cache(2, 10, 8, MAX_LEN)
        with pytest.raises(ValueError,
                           match=r"block-table rows") as e:
            model.decode_step(params, jnp.zeros((5, 1), jnp.int32), cache)
        assert "init_paged_cache" in str(e.value)

    def test_block_size_must_divide_max_len(self, cell):
        model, _, _ = cell
        with pytest.raises(ValueError, match="does not divide"):
            model.init_paged_cache(2, 10, 7, MAX_LEN)


# ---------------------------------------------------------------------------
# the per-row ring-slot decode fix (serve-path bug)
# ---------------------------------------------------------------------------

class TestPerRowCacheRows:
    def test_mixed_length_rows_decode_independently(self, cell):
        """Rows at different lengths in ONE cache must each write their
        own ring slot: before the fix, every row wrote row 0's slot,
        corrupting any batch whose lengths diverged (exactly the
        continuous-batching state)."""
        model, _, params = cell
        prompts = _prompts(3, model.cfg.vocab_size, seed=11)  # 6,7,8 long
        solo_caches = []
        toks = []
        for p in prompts:
            c = model.init_cache(1, MAX_LEN, dtype=jnp.float32)
            lg, c = jax.jit(model.prefill)(
                params, {"tokens": jnp.asarray(p[None])}, c)
            solo_caches.append(c)
            toks.append(int(jnp.argmax(lg[0, -1])))
        pool = SlotPool(model, 3, MAX_LEN)
        for i, c in enumerate(solo_caches):
            pool.adopt(i, c)
        tok = jnp.asarray(np.asarray(toks, np.int32)[:, None])
        batched_logits, _ = jax.jit(model.decode_step)(
            params, tok, pool.cache)
        for i in range(3):
            solo_logits, _ = jax.jit(model.decode_step)(
                params, tok[i:i + 1], solo_caches[i])
            np.testing.assert_array_equal(
                np.asarray(batched_logits[i]), np.asarray(solo_logits[0]),
                err_msg=f"row {i} (len {prompts[i].size}) diverged")

# ---------------------------------------------------------------------------
# speculative decode (ISSUE 10 tentpole)
# ---------------------------------------------------------------------------

def _oracle_draft(model, params, prompts, gens, wrong_every=None):
    """A ``draft_source`` proposing the known greedy continuation.

    ``wrong_every=j`` corrupts every j-th generated position (j=1 means
    every proposal is wrong); ``None`` proposes perfectly.  Returns the
    draft fn plus the solo references for parity assertions.
    """
    refs = [_solo_decode(model, params, p, g)
            for p, g in zip(prompts, gens)]
    vocab = model.cfg.vocab_size

    def draft(active, tok, k):
        out = np.zeros((tok.shape[0], k), np.int32)
        for slot, req in active.items():
            ref = refs[req.rid % len(refs)]
            pos = len(req.tokens)          # next position to generate
            for i in range(k):
                t = ref[pos + i]
                if wrong_every and (pos + i) % wrong_every == 0:
                    t = (t + 1) % vocab
                out[slot, i] = t
        return out

    return draft, refs


class TestSpeculativeDecode:
    def test_branch_draft_bit_identical_both_pools(self, cell):
        """The headline invariant: spec mode with the REAL branch-only
        draft model (trunk_skip) returns exactly the non-speculative
        greedy tokens — mixed prompt lengths, staggered retirement,
        dense and paged pools."""
        model, _, params = cell
        gens = [4, 7, 3, 6, 5]
        for pool in (SlotPool(model, 2, MAX_LEN),
                     PagedPool(model, 4, 18, 8, MAX_LEN)):
            b = ContinuousBatcher(model, params, pool, spec_k=3)
            prompts = _prompts(5, model.cfg.vocab_size)
            reqs = [b.submit(p, g) for p, g in zip(prompts, gens)]
            b.drain(max_steps=500)
            for r, p, g in zip(reqs, prompts, gens):
                assert r.tokens == _solo_decode(model, params, p, g), \
                    f"request {r.rid} diverged ({type(pool).__name__})"
            assert pool.occupancy == 0
        assert b.spec_rounds > 0 and b.drafted_total > 0

    def test_partial_acceptance_parity_and_accounting(self, cell):
        """An oracle draft that misses every 3rd position still yields
        bit-identical output, and the drafted/matched counters add up."""
        model, _, params = cell
        prompts = _prompts(4, model.cfg.vocab_size, seed=5)
        gens = [6, 8, 5, 7]
        draft, refs = _oracle_draft(model, params, prompts, gens,
                                    wrong_every=3)
        pool = SlotPool(model, 2, MAX_LEN)
        b = ContinuousBatcher(model, params, pool, spec_k=4,
                              draft_source=draft)
        reqs = [b.submit(p, g) for p, g in zip(prompts, gens)]
        b.drain(max_steps=500)
        for r, ref in zip(reqs, refs):
            assert r.tokens == ref
        assert 0.0 < b.acceptance_rate < 1.0
        assert b.drafted_total == sum(r.drafted for r in reqs)
        assert b.matched_total == sum(r.matched for r in reqs)
        for r in reqs:
            assert 0 <= r.matched <= r.drafted
            # every round lands >=1 token, so at most gen rounds of <=k
            assert r.drafted <= 4 * len(r.tokens)

    def test_rejected_drafts_never_leak_blocks(self, cell):
        """An always-wrong draft forces a full rollback every round;
        the paged pool's block accounting must still balance to zero
        and the output must still be exact (each round lands the one
        corrected token)."""
        model, _, params = cell
        prompts = _prompts(3, model.cfg.vocab_size, seed=2)
        gens = [5, 6, 4]
        draft, refs = _oracle_draft(model, params, prompts, gens,
                                    wrong_every=1)
        pool = PagedPool(model, 3, 18, 8, MAX_LEN)
        b = ContinuousBatcher(model, params, pool, spec_k=4,
                              draft_source=draft)
        reqs = [b.submit(p, g) for p, g in zip(prompts, gens)]
        high = 0
        while not b.idle:
            b.step()
            high = max(high, pool.blocks_in_use)
            assert b.step_count < 500
        for r, ref in zip(reqs, refs):
            assert r.tokens == ref
        assert b.acceptance_rate == 0.0
        assert high > 0
        assert pool.blocks_in_use == 0 and pool.blocks_reserved == 0
        assert pool.occupancy == 0

    def test_midstream_scenario_swap_under_spec(self, cell):
        """A scenario swap queued while spec rounds are in flight must
        hold until the admitted requests finish, then requests admitted
        under the new branch must match ITS solo greedy decode — the
        draft shadow cache swaps along with the verify path."""
        from repro.scenario import swap_params
        model, _, pA = cell
        brB = jax.tree.map(
            lambda x: x + jnp.asarray(0.02, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            rebranch.partition(pA)[0])
        pB = swap_params(jax.tree.map(jnp.array, pA), brB)
        pool = SlotPool(model, 2, MAX_LEN)
        b = ContinuousBatcher(model, jax.tree.map(jnp.array, pA), pool,
                              scenario="a", spec_k=2)
        prompts = _prompts(2, model.cfg.vocab_size, seed=13)
        r1 = b.submit(prompts[0], 6, scenario="a")
        b.step()                                # spec round under A
        assert r1.admit_step >= 0 and not r1.done
        b.swap("b", brB)
        b.step()
        assert b.scenario == "a"                # barrier held
        r2 = b.submit(prompts[1], 5, scenario="b")
        b.drain(max_steps=200)
        assert b.scenario == "b" and b.swap_count == 1
        assert r1.tokens == _solo_decode(model, pA, prompts[0], 6)
        assert r2.tokens == _solo_decode(model, pB, prompts[1], 5)

    def test_verify_block_wider_than_horizon_raises(self, cell):
        model, _, params = cell
        cache = model.init_cache(2, 16, dtype=jnp.float32)
        with pytest.raises(ValueError, match="horizon"):
            model.verify_step(params,
                              jnp.zeros((2, 17), jnp.int32), cache)

    def test_trunk_skip_is_branch_only_math(self):
        """apply_linear under trunk_skip == the closed-form branch
        (x@C)@(core@U): no trunk contribution, no engine dispatch."""
        spec = rebranch.ReBranchSpec(d_ratio=2, u_ratio=2)
        key = jax.random.PRNGKey(3)
        p = rebranch.init_linear(key, 16, 12, spec, use_bias=True)
        p["sram"]["core"] = jax.random.normal(
            jax.random.PRNGKey(4), p["sram"]["core"].shape,
            p["sram"]["core"].dtype)
        x = jax.random.normal(jax.random.PRNGKey(5), (3, 16))
        skip = dataclasses.replace(spec, trunk_skip=True)
        y = rebranch.apply_linear(p, x, skip)
        core_u = p["sram"]["core"].astype(x.dtype) @ p["rom"]["U"].astype(
            x.dtype)
        want = (x @ p["rom"]["C"].astype(x.dtype)) @ core_u \
            + p["sram"]["b"].astype(x.dtype)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        # branchless ROM site: the draft contributes exactly zero
        solo = rebranch.ReBranchSpec(branch_enabled=False, trunk_skip=True)
        p2 = rebranch.init_linear(key, 16, 12,
                                  dataclasses.replace(
                                      solo, trunk_skip=False))
        np.testing.assert_array_equal(
            np.asarray(rebranch.apply_linear(p2, x, solo)),
            np.zeros((3, 12), np.float32))

    def test_draft_config_flips_every_enabled_site(self, cell):
        model, _, _ = cell
        cfg = model.cfg
        dcfg = api.draft_config(cfg)
        if cfg.rebranch.enabled:
            assert dcfg.rebranch.trunk_skip
        for _site, spec in dcfg.rebranch_overrides:
            if spec.enabled:
                assert spec.trunk_skip
        # idempotent: a draft of a draft is the same config
        assert api.draft_config(dcfg) == dcfg

    def test_spec_rejected_for_recurrent_families(self):
        cfg = configs.get_smoke("falcon_mamba_7b")
        assert not api.supports_speculation(cfg)
        model = deploy.compile_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        pool = SlotPool(model, 1, 32)
        with pytest.raises(ValueError, match="spec_k=0"):
            ContinuousBatcher(model, params, pool, spec_k=2)
        with pytest.raises(ValueError, match="speculative verify"):
            cache = model.init_cache(1, 32, dtype=jnp.float32)
            model.verify_step(params, jnp.zeros((1, 2), jnp.int32), cache)

    def test_spec_k_validation(self, cell):
        model, _, params = cell
        with pytest.raises(ValueError, match="spec_k"):
            ContinuousBatcher(model, params, SlotPool(model, 1, MAX_LEN),
                              spec_k=-1)


# ---------------------------------------------------------------------------
# paged-pool rollback primitive (spec decode's undo path)
# ---------------------------------------------------------------------------

class TestPoolRollback:
    def test_prepare_tokens_grants_then_rollback_returns_tail(self, cell):
        model, _, params = cell
        pool = PagedPool(model, 2, 12, 8, MAX_LEN)
        cache = pool.solo_cache()
        prompt = _prompts(1, model.cfg.vocab_size)[0]   # 6 tokens
        _, cache = jax.jit(model.prefill)(
            params, {"tokens": jnp.asarray(np.asarray(prompt)[None])},
            cache)
        row = pool.try_admit(prompt.size + 10)
        pool.adopt(row, cache)
        start_len = int(prompt.size)
        before = pool.blocks_in_use
        reserved = pool.blocks_reserved
        pool.prepare_tokens(4)               # room for a k=4 verify block
        grown = pool.blocks_in_use
        assert grown > before                # 6+4=10 spans block 2
        pool.rollback({row: start_len + 1})  # keep 1 accepted token
        assert pool.blocks_in_use == before  # tail block came back
        assert pool.blocks_reserved == reserved  # reservation re-credited
        assert pool._len[row] == start_len + 1
        # re-granting after a rollback reuses the freed tail blocks
        pool.prepare_tokens(4)
        assert pool.blocks_in_use == grown
        pool.release(row)
        assert pool.blocks_in_use == 0 and pool.blocks_reserved == 0

    def test_rollback_validation(self, cell):
        model, _, _ = cell
        pool = PagedPool(model, 2, 12, 8, MAX_LEN)
        with pytest.raises(ValueError, match="holds no blocks"):
            pool.rollback({0: 5})            # row never admitted
        with pytest.raises(ValueError, match="at least one token"):
            pool.prepare_tokens(0)
        row = pool.try_admit(10)
        pool.prepare_tokens(3)
        with pytest.raises(ValueError, match="only ever truncates"):
            pool.rollback({row: 99})         # growth is not a rollback
        pool.release(row)


# ---------------------------------------------------------------------------
# registry LRU residency cap (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

class TestRegistryLRU:
    def _mini(self, name, size):
        serve.register(serve.ModelEntry(
            name, config=lambda: cnn.CNNConfig(name="vgg8",
                                               input_size=size)),
            override=True)

    def test_cap_evicts_oldest_and_hits_refresh_recency(self):
        for n, s in (("lru-a", 16), ("lru-b", 16), ("lru-c", 16)):
            self._mini(n, s)
        try:
            serve.set_max_resident(2)
            ma, _ = serve.compile_entry("lru-a")
            serve.compile_entry("lru-b")
            assert "lru-a" in serve.resident_ids()
            serve.compile_entry("lru-a")     # hit: a becomes most-recent
            serve.compile_entry("lru-c")     # evicts b, NOT a
            ids = serve.resident_ids()
            assert "lru-b" not in ids and "lru-a" in ids and "lru-c" in ids
            assert len(ids) <= 2
            ma2, _ = serve.compile_entry("lru-a")
            assert ma2 is ma                 # survivor kept its cell
        finally:
            serve.set_max_resident(None)
            for n in ("lru-a", "lru-b", "lru-c"):
                serve.evict(n)

    def test_evicted_id_recompiles_fresh(self):
        self._mini("lru-d", 16)
        m1, _ = serve.compile_entry("lru-d")
        assert serve.evict("lru-d")
        assert not serve.evict("lru-d")      # idempotent: already gone
        m2, _ = serve.compile_entry("lru-d")
        assert m2 is not m1
        serve.evict("lru-d")

    def test_cap_validation(self):
        with pytest.raises(ValueError, match="max_resident"):
            serve.set_max_resident(0)
        assert serve.max_resident() is None
