"""The PlacementPlan subsystem: site trees, plans, solver, deploy parity.

Covers the placement contract:
  * site enumeration — every ReBranch-capable parameter group of each
    family config maps to exactly one leaf site (parametrized over
    transformer / cnn / ssm / hybrid / moe), and the site tree's weight
    counts match the actual initialised parameters;
  * PlacementPlan — round-trip through rebranch_overrides, longest-prefix
    resolution, unknown / duplicate sites raise;
  * plan.solve — Fig. 12 qualitative shape on DarkNet-19 (small early /
    late layers flip to SRAM first, bulk mid convs stay ROM), budget
    monotonicity, stats/area bookkeeping;
  * deploy.compile_model(cfg, plan=...) — bit-identical to the
    equivalent hand-written rebranch_overrides deployment for all three
    builtin engines.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import deploy, plan
from repro.models import api, cnn
from repro.models.config import ArchConfig, spec_for

ENGINES = ["int8_native", "dequant", "pallas"]


def _lm_cfg(**kw):
    base = dict(name="t_plan", family="dense", num_layers=2, d_model=32,
                num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=128,
                remat=False, dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


FAMILY_CFGS = {
    "transformer": _lm_cfg(),
    "moe": _lm_cfg(name="m_plan", family="moe", num_experts=4,
                   num_experts_per_tok=2, moe_d_ff=32,
                   num_shared_experts=1),
    "ssm": _lm_cfg(name="s_plan", family="ssm", num_heads=0,
                   num_kv_heads=0, d_ff=0, ssm_state=4),
    "hybrid": _lm_cfg(name="h_plan", family="hybrid", ssm_state=4,
                      sliding_window=8, full_attn_layers=(0,)),
    "cnn": cnn.CNNConfig(name="vgg8", num_classes=13, input_size=16),
    "cnn_resnet": cnn.CNNConfig(name="resnet18", num_classes=13,
                                input_size=16),
    "cnn_darknet": cnn.CNNConfig(name="tiny_yolo", input_size=32),
}


def _init_params(cfg):
    if isinstance(cfg, cnn.CNNConfig):
        init_fn, _ = cnn.MODEL_REGISTRY[cfg.name]
        return jax.eval_shape(lambda k: init_fn(k, cfg),
                              jax.random.PRNGKey(0))
    return jax.eval_shape(lambda k: api.init(k, cfg), jax.random.PRNGKey(0))


def _rebranch_groups(params):
    """Paths of every ReBranch-capable parameter group: dict nodes holding
    a ROM trunk image ({'rom': {'w_q': ...}}) — exactly the groups a site
    governs.  Embedding tables (ROM but never remappable) are excluded."""
    out = []

    def walk(path, node):
        if isinstance(node, dict):
            if "rom" in node and isinstance(node["rom"], dict) \
                    and "w_q" in node["rom"]:
                out.append(path)
                return
            for k, v in node.items():
                walk(path + (k,), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(path + (i,), v)

    walk((), params)
    return out


def _trunk_weights(params):
    """Total trunk (w_q) weight count over all ReBranch groups."""
    total = 0

    def walk(node):
        nonlocal total
        if isinstance(node, dict):
            if "rom" in node and isinstance(node["rom"], dict) \
                    and "w_q" in node["rom"]:
                total += int(np.prod(node["rom"]["w_q"].shape))
                return
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return total


# ---------------------------------------------------------------------------
# site enumeration
# ---------------------------------------------------------------------------

class TestSiteTrees:
    @pytest.mark.parametrize("family", sorted(FAMILY_CFGS))
    def test_every_group_maps_to_exactly_one_site(self, family):
        """Each ReBranch parameter group resolves to exactly ONE leaf site
        — flipping that site (and only it) to SRAM removes the group's
        ROM image; every other group keeps its placement."""
        cfg = FAMILY_CFGS[family]
        tree = plan.site_tree(cfg)
        names = [s.name for s in tree]
        assert len(names) == len(set(names))          # leaves are unique
        # leaf sites never nest (a leaf being another leaf's prefix would
        # make resolution ambiguous)
        for a in names:
            for b in names:
                assert a == b or not b.startswith(a + "."), (a, b)
        groups = _rebranch_groups(_init_params(cfg))
        assert groups, family
        n_groups = len(groups)
        for site in tree:
            sram = dataclasses.replace(cfg.rebranch, enabled=False)
            cfg2 = dataclasses.replace(
                cfg, rebranch_overrides=((site.name, sram),))
            remaining = _rebranch_groups(_init_params(cfg2))
            # the site governs >= 1 group; every group it governed is gone
            # and none of the others moved
            assert len(remaining) < n_groups, site.name
            assert set(remaining) <= set(groups), site.name
        # all sites SRAM -> no ROM groups anywhere: the tree COVERS the
        # model (no group escapes the enumeration)
        all_sram = dataclasses.replace(cfg.rebranch, enabled=False)
        cfg3 = dataclasses.replace(
            cfg, rebranch_overrides=tuple((n, all_sram) for n in names))
        leftovers = _rebranch_groups(_init_params(cfg3))
        # embeddings are the one always-ROM non-site group in LM families
        assert all(p[0] == "embed" for p in leftovers), leftovers

    @pytest.mark.parametrize("family", sorted(FAMILY_CFGS))
    def test_site_weight_counts_match_params(self, family):
        """The tree's trunk weight totals equal the actually-initialised
        ROM image (the cost model prices real bits, not estimates)."""
        cfg = FAMILY_CFGS[family]
        tree = plan.site_tree(cfg)
        want = _trunk_weights(_init_params(cfg))
        got = sum(s.total_weights for s in tree)
        assert got == want, (family, got, want)

    def test_moe_branch_costs_match_real_shapes(self):
        """The MoE expert stacks share ONE C/U pair per stack with a
        per-expert core (models.moe.init_expert_linear) — branch pricing
        must match those actual array sizes, not densify C per expert."""
        cfg = FAMILY_CFGS["moe"]
        site = next(s for s in plan.site_tree(cfg)
                    if s.name == "blocks.moe")
        proj_w, core_w, bmacs = site.branch_costs(cfg.rebranch)
        params = _init_params(cfg)
        layers0 = params["layers"]                    # stacked (scan)
        want_proj = want_core = 0
        for blk in ("gate", "up", "down"):
            p = jax.tree.map(lambda a: a, layers0["moe"]["experts"][blk])
            # leading L dim from vmap-stacked init: strip it
            want_proj += (int(np.prod(p["rom"]["C"].shape[1:]))
                          + int(np.prod(p["rom"]["U"].shape[1:])))
            want_core += int(np.prod(p["sram"]["core"].shape[1:]))
        for blk in ("gate", "up", "down"):
            sh = layers0["moe"]["shared"][blk]
            want_proj += (int(np.prod(sh["rom"]["C"].shape[1:]))
                          + int(np.prod(sh["rom"]["U"].shape[1:])))
            want_core += int(np.prod(sh["sram"]["core"].shape[1:]))
        assert proj_w == want_proj, (proj_w, want_proj)
        assert core_w == want_core, (core_w, want_core)
        # branch MACs: top-k active experts + the always-on shared expert
        d, ff = cfg.d_model, cfg.moe_d_ff
        k, dr, ur = cfg.num_experts_per_tok, cfg.rebranch.d_ratio, \
            cfg.rebranch.u_ratio
        per = lambda a, b: (a * max(1, a // dr) + max(1, a // dr)
                            * max(1, b // ur) + max(1, b // ur) * b)
        sff = cfg.num_shared_experts * ff
        want = k * (2 * per(d, ff) + per(ff, d)) \
            + 2 * per(d, sff) + per(sff, d)
        assert bmacs == want, (bmacs, want)

    def test_ssm_head_site_unconditional(self):
        """ssm/hybrid init always build lm_head, even under
        tie_embeddings — the site tree must list it."""
        cfg = dataclasses.replace(FAMILY_CFGS["ssm"], tie_embeddings=True)
        names = {s.name for s in plan.site_tree(cfg)}
        assert "lm_head" in names

    def test_unknown_family_raises(self):
        cfg = dataclasses.replace(_lm_cfg(), family="novel")
        with pytest.raises(ValueError, match="novel"):
            plan.site_tree(cfg)
        assert plan.try_site_tree(cfg) is None

    def test_valid_addresses_include_prefixes(self):
        tree = plan.site_tree(FAMILY_CFGS["hybrid"])
        addrs = plan.valid_addresses(tree)
        assert {"blocks", "blocks.ssm", "blocks.ssm.in_proj",
                "blocks.attn", "lm_head"} <= addrs


# ---------------------------------------------------------------------------
# PlacementPlan semantics
# ---------------------------------------------------------------------------

class TestPlacementPlan:
    def test_round_trips_through_config(self):
        cfg = FAMILY_CFGS["cnn"]
        p = plan.PlacementPlan.build(cfg, {
            "convs.0": {"memory": "sram"},
            "convs.2": {"engine": "dequant"}})
        model = deploy.compile_model(cfg, plan=p)
        back = plan.PlacementPlan.from_config(model.cfg)
        assert back.entries == p.entries
        assert back.spec("convs.0").enabled is False
        assert back.engine("convs.2") == "dequant"
        assert back.residency("convs.1") == "rom"     # default untouched

    def test_unknown_site_raises_with_valid_set(self):
        cfg = FAMILY_CFGS["cnn"]
        with pytest.raises(ValueError, match="convs.0"):
            plan.PlacementPlan.build(cfg, {"conv.0": {"memory": "sram"}})

    def test_duplicate_site_raises(self):
        cfg = FAMILY_CFGS["cnn"]
        sram = dataclasses.replace(cfg.rebranch, enabled=False)
        with pytest.raises(ValueError, match="duplicate"):
            plan.PlacementPlan.build(
                cfg, [("convs.0", sram), ("convs.0", sram)])

    def test_prefix_resolution_longest_wins(self):
        cfg = FAMILY_CFGS["hybrid"]
        sram = dataclasses.replace(cfg.rebranch, enabled=False)
        deq = dataclasses.replace(cfg.rebranch, trunk_impl="dequant")
        p = plan.PlacementPlan.build(
            cfg, {"blocks.ssm": sram, "blocks.ssm.x_proj": deq})
        assert p.residency("blocks.ssm.in_proj") == "sram"
        assert p.spec("blocks.ssm.x_proj") is deq     # longest prefix wins
        assert p.residency("blocks.attn") == "rom"
        # and spec_for agrees once folded into the config
        cfg2 = deploy.compile_model(cfg, plan=p).cfg
        assert spec_for(cfg2, "blocks.ssm.in_proj").enabled is False
        assert spec_for(cfg2, "blocks.ssm.x_proj").trunk_impl == "dequant"

    def test_plan_is_hashable_static(self):
        p = plan.PlacementPlan.build(FAMILY_CFGS["cnn"],
                                     {"convs.0": {"memory": "sram"}})
        hash(p)

    def test_stats_bookkeeping(self):
        cfg = FAMILY_CFGS["cnn"]
        s_all_rom = plan.PlacementPlan.build(cfg, {}).stats(cfg)
        assert s_all_rom.sram_sites == 0 and s_all_rom.sram_bits == 0
        assert s_all_rom.branch_bits > 0              # branches live
        sram = dataclasses.replace(cfg.rebranch, enabled=False)
        s_mix = plan.PlacementPlan.build(
            cfg, {"convs.0": sram}).stats(cfg)
        assert s_mix.sram_sites == 1
        assert s_mix.rom_bits < s_all_rom.rom_bits
        # total trunk bits conserved regardless of residency
        assert s_mix.weight_bits_total == s_all_rom.weight_bits_total
        # no-branch plan: ROM trunk only
        bare = dataclasses.replace(cfg.rebranch, branch_enabled=False)
        s_bare = plan.PlacementPlan(model=cfg.name, default=bare).stats(cfg)
        assert s_bare.branch_bits == 0 and s_bare.branch_macs == 0


# ---------------------------------------------------------------------------
# the cost-driven solver (Fig. 12)
# ---------------------------------------------------------------------------

class TestSolve:
    def test_darknet19_fig12_shape(self):
        """Mid-budget solve on DarkNet-19 reproduces the paper's Fig. 12
        qualitative shape: small early layers + late 1x1 bottlenecks go
        SRAM-trainable, the bulk wide mid/late 3x3 convs stay ROM."""
        from repro.configs.paper_models import PAPER_MODELS
        cfg = PAPER_MODELS["darknet19"]
        recs = plan.sweep(cfg, 5, reload_factor=3.0)
        mid = recs[1]["plan"]
        assert 0 < recs[1]["sram_sites"] < recs[1]["rom_sites"] \
            + recs[1]["sram_sites"]
        # early small convs flip to SRAM first
        assert mid.residency("convs.0") == "sram"
        assert mid.residency("convs.1") == "sram"
        # late 1x1 bottlenecks (512->256, 1024->512) are cheap: SRAM
        assert mid.residency("convs.9") == "sram"
        assert mid.residency("convs.16") == "sram"
        # the bulk wide 3x3 convs (3x3x512x1024 +) and head stay ROM
        for site in ("convs.13", "convs.15", "convs.17",
                     "head.0", "head.1"):
            assert mid.residency(site) == "rom", site

    def test_budget_monotone(self):
        from repro.configs.paper_models import PAPER_MODELS
        cfg = PAPER_MODELS["tiny_yolo"]
        recs = plan.sweep(cfg, 4)
        n_sram = [r["sram_sites"] for r in recs]
        assert n_sram == sorted(n_sram)
        assert n_sram[0] == 0                          # all-ROM floor
        assert n_sram[-1] == len(plan.site_tree(cfg))  # all-SRAM ceiling
        areas = [r["area_mm2"] for r in recs]
        assert all(a <= b + 1e-9 for a, b in zip(areas, recs and areas[1:]))
        # spending area buys energy headroom in the BASELINE's favour:
        # efficiency over iso-area SRAM shrinks toward 1x
        effs = [r["efficiency_x"] for r in recs]
        assert effs == sorted(effs, reverse=True)

    def test_budget_below_floor_clamps_to_all_rom(self):
        cfg = FAMILY_CFGS["cnn"]
        p = plan.solve(cfg, 0.001)
        assert all(s.enabled for _, s in p.entries) or not p.entries

    def test_solve_works_on_lm_families(self):
        """The planner is family-generic: an SSM config solves too."""
        cfg = FAMILY_CFGS["ssm"]
        stats = plan.solve(cfg).stats(cfg)
        assert stats.rom_sites == len(plan.site_tree(cfg))
        hi = plan.sweep(cfg, 3)[-1]
        assert hi["sram_sites"] == stats.rom_sites


# ---------------------------------------------------------------------------
# deploy integration: plan= is bit-identical to hand-written overrides
# ---------------------------------------------------------------------------

class TestDeployParity:
    @pytest.mark.parametrize("engine_name", ENGINES)
    def test_plan_equals_handwritten_overrides_cnn(self, engine_name):
        cfg = cnn.CNNConfig(name="tiny_yolo", input_size=16,
                            head_anchors=2, head_classes=3)
        overrides = {"convs.0": {"memory": "sram"},
                     "convs.2": {"memory": "sram"},
                     "head.0": {"memory": "sram"}}
        p = plan.PlacementPlan.build(
            cfg, overrides,
            default=dataclasses.replace(cfg.rebranch,
                                        trunk_impl=engine_name))
        m_plan = deploy.compile_model(cfg, plan=p)
        m_hand = deploy.compile_model(cfg, engine=engine_name,
                                      layer_overrides=overrides)
        assert m_plan.cfg == m_hand.cfg               # identical mapping
        params = m_plan.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
        np.testing.assert_array_equal(
            np.asarray(m_plan.forward(params, x)),
            np.asarray(m_hand.forward(params, x)))

    def test_solved_plan_deploys_end_to_end(self):
        cfg = cnn.CNNConfig(name="vgg8", num_classes=7, input_size=16)
        budget = plan.sweep(cfg, 3)[1]["budget_mm2"]
        p = plan.solve(cfg, budget)
        model = deploy.compile_model(cfg, plan=p)
        params = model.init(jax.random.PRNGKey(0))
        # SRAM sites initialise as plain trainable convs (no ROM image)
        for site, spec in p.entries:
            if not spec.enabled and site.startswith("convs."):
                idx = int(site.split(".")[1])
                assert "rom" not in params["convs"][idx], site
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
        y = model.forward(params, x)
        assert y.shape == (2, 7) and bool(jnp.all(jnp.isfinite(y)))

    def test_plan_layer_overrides_mutually_exclusive(self):
        cfg = FAMILY_CFGS["cnn"]
        p = plan.PlacementPlan.build(cfg, {})
        with pytest.raises(ValueError, match="not both"):
            deploy.compile_model(cfg, plan=p,
                                 layer_overrides={"convs.0":
                                                  {"memory": "sram"}})

    def test_plan_replaces_stale_config_overrides(self):
        """An explicit plan is canonical: a leaf override already folded
        into the config must not out-length and shadow the plan's
        ancestor-prefix entry."""
        cfg = FAMILY_CFGS["transformer"]
        cfg2 = deploy.compile_model(
            cfg, layer_overrides={"blocks.attn": {"memory": "sram"}}).cfg
        rom = cfg2.rebranch                            # enabled=True
        p = plan.PlacementPlan.build(cfg2, {"blocks": rom})
        cfg3 = deploy.compile_model(cfg2, plan=p).cfg
        assert spec_for(cfg3, "blocks.attn").enabled is True
        assert spec_for(cfg3, "blocks.attn") is p.spec("blocks.attn")

    def test_plan_for_wrong_config_raises(self):
        p = plan.PlacementPlan.build(FAMILY_CFGS["cnn"], {})
        with pytest.raises(ValueError, match="vgg8"):
            deploy.compile_model(FAMILY_CFGS["cnn_resnet"], plan=p)

    def test_lm_prefix_plan_forward(self):
        """A 'blocks' prefix entry governs the refined sub-sites — the
        pre-refactor override surface keeps working."""
        cfg = FAMILY_CFGS["transformer"]
        sram = dataclasses.replace(cfg.rebranch, enabled=False)
        p = plan.PlacementPlan.build(cfg, {"blocks": sram})
        model = deploy.compile_model(cfg, plan=p)
        params = model.init(jax.random.PRNGKey(0))
        flat = jax.tree_util.tree_leaves_with_path(params["layers"])
        assert not any("rom" in jax.tree_util.keystr(kp) for kp, _ in flat)
        out = model.forward(params, {"tokens":
                                     jnp.ones((2, 4), jnp.int32)})
        assert out.shape == (2, 4, cfg.vocab_size)


# ---------------------------------------------------------------------------
# per-site overrides inside ssm / hybrid (newly wired families)
# ---------------------------------------------------------------------------

class TestSsmHybridSites:
    @pytest.mark.parametrize("family", ["ssm", "hybrid"])
    def test_per_site_override_changes_only_that_group(self, family):
        cfg = FAMILY_CFGS[family]
        prefix = "blocks" if family == "ssm" else "blocks.ssm"
        model = deploy.compile_model(
            cfg, layer_overrides={f"{prefix}.x_proj": {"memory": "sram"}})
        params = model.init(jax.random.PRNGKey(0))
        layer0 = (jax.tree.map(lambda a: a, params["layers"])
                  if cfg.scan_layers else params["layers"][0])
        blk = layer0["ssm"] if family == "hybrid" else layer0["ssm"]
        assert "rom" not in blk["x_proj"]              # flipped to SRAM
        assert "rom" in blk["in_proj"]                 # untouched
        out = model.forward(params,
                            {"tokens": jnp.ones((2, 4), jnp.int32)})
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_spec_for_is_identity_without_overrides(self):
        cfg = FAMILY_CFGS["ssm"]
        assert spec_for(cfg, "blocks.in_proj") is cfg.rebranch


# ---------------------------------------------------------------------------
# plan-aware pricing stays wired to the Fig. 12 cost model
# ---------------------------------------------------------------------------

class TestCostWiring:
    def test_all_rom_area_tracks_energy_module(self):
        """plan area ~ core.energy.yoloc_area on the same net (same
        densities; plan adds the explicit C/U projection bits the
        branch_fraction shorthand folds away)."""
        from repro.configs.paper_models import PAPER_MODELS
        from repro.core import energy
        from benchmarks import netstats
        cfg = PAPER_MODELS["tiny_yolo"]
        stats = plan.solve(cfg).stats(cfg)
        got = plan.plan_area_mm2(stats)
        want = energy.yoloc_area(netstats.paper_net_stats()["tiny_yolo"])
        assert abs(got - want) / want < 0.30           # same ballpark
        # and the area RATIO to all-SRAM is Fig. 12's headline direction
        tree = plan.site_tree(cfg)
        all_sram_bits = sum(s.total_weights for s in tree) * 8
        cm = energy.DEFAULT_COST
        ratio = (all_sram_bits / 1e6 / cm.sram_density_mb_mm2) / got
        assert ratio > 5.0                             # ROM wins big
