"""Distribution-layer tests that need >1 device: run small sharded
programs in a subprocess with forced host devices (kept OUT of this
process so other tests see 1 device, per the dry-run rule)."""

import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 300) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """The sharded train step on a 4x2 mesh computes the same loss as the
    unsharded one — sharding is semantics-preserving."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs, optim
        from repro.core import rebranch
        from repro.data import synthetic
        from repro.distributed import sharding as shd
        from repro.launch import steps as steps_lib

        cfg = configs.get_smoke('gemma_2b')
        dcfg = synthetic.DataConfig(seed=0, vocab_size=cfg.vocab_size,
                                    seq_len=32, global_batch=8)
        params = jax.tree.map(lambda x: x,
                              __import__('repro.models.api', fromlist=['x'])
                              .init(jax.random.PRNGKey(0), cfg))
        t, f = rebranch.partition(params)
        opt = optim.init(t)
        batch = synthetic.markov_batch(dcfg, 0)
        step = steps_lib.make_train_step(cfg, optim.AdamWConfig(lr=1e-3),
                                         loss_chunks=2)

        # single device
        _, _, m1 = jax.jit(step)(t, f, opt, batch)

        # sharded 4x2 mesh
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        with shd.use_mesh(mesh), mesh:
            t_sh, f_sh, opt_sh, _ = steps_lib.model_state_shardings(cfg, mesh)
            in_sh = steps_lib.batch_shardings(
                cfg, mesh,
                steps_lib.input_specs(cfg, 32, 8, 'train'), 8)
            jstep = jax.jit(step, in_shardings=(t_sh, f_sh, opt_sh, in_sh))
            _, _, m2 = jstep(t, f, opt, batch)
        l1, l2 = float(m1['loss']), float(m2['loss'])
        assert abs(l1 - l2) < 2e-2 * max(abs(l1), 1.0), (l1, l2)
        print('OK', l1, l2)
    """)
    assert "OK" in out


def test_serve_step_sharded_decode():
    """Sharded decode on a mesh produces the same next token."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.distributed import sharding as shd
        from repro.launch import steps as steps_lib
        from repro.models import api

        cfg = configs.get_smoke('yi_34b')
        params = api.init(jax.random.PRNGKey(0), cfg)
        cache = api.init_cache(cfg, 8, 32, dtype=jnp.float32)
        batch = {'tokens': jnp.ones((8, 1), jnp.int32)}
        step = steps_lib.make_serve_step(cfg)
        tok1, _ = jax.jit(step)(params, batch, cache)

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        with shd.use_mesh(mesh), mesh:
            t_sh, f_sh, _, _ = steps_lib.model_state_shardings(cfg, mesh)
            from repro.core import rebranch
            c_sh = steps_lib.cache_shardings(cfg, mesh, cache)
            in_sh = steps_lib.batch_shardings(
                cfg, mesh, steps_lib.input_specs(cfg, 32, 8, 'decode'), 8)
            jstep = jax.jit(step, in_shardings=(
                rebranch.combine(t_sh, f_sh), in_sh, c_sh))
            tok2, _ = jstep(params, batch, cache)
        same = float(jnp.mean((tok1 == tok2).astype(jnp.float32)))
        assert same > 0.99, same
        print('OK', same)
    """)
    assert "OK" in out


def test_int8_compressed_allreduce_matches_plain():
    """shard_map int8 EF all-reduce ~= plain psum mean over the data axis."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.optim import compress
        try:
            shard_map = jax.shard_map
        except AttributeError:              # jax < 0.5: experimental home
            from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh((8,), ('data',))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 1e-3
        err = jnp.zeros((8, 64))

        @partial(shard_map, mesh=mesh,
                 in_specs=(P('data'), P('data')),
                 out_specs=(P('data'), P('data')))
        def compressed(gs, es):
            r, e = compress.all_reduce_int8(gs[0], es[0], 'data')
            return r[None], e[None]

        red, _ = compressed(g, err)
        want = jnp.mean(g, axis=0)
        got = red[0]
        err_rel = float(jnp.max(jnp.abs(got - want)) /
                        (jnp.max(jnp.abs(want)) + 1e-12))
        assert err_rel < 0.05, err_rel
        print('OK', err_rel)
    """)
    assert "OK" in out


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint on an 8-device mesh, restore on 4 devices (elastic)."""
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs, optim
        from repro.checkpoint import manager as ckpt
        from repro.core import rebranch
        from repro.distributed import sharding as shd
        from repro.models import api

        cfg = configs.get_smoke('gemma_2b')
        params = api.init(jax.random.PRNGKey(0), cfg)
        t, f = rebranch.partition(params)
        opt = optim.init(t)
        ckpt.save({str(tmp_path)!r}, 3, t, opt, params)

        # restore re-sharded onto a DIFFERENT (smaller) mesh
        mesh = jax.make_mesh((2, 2), ('data', 'model'))
        with shd.use_mesh(mesh), mesh:
            from repro.launch import steps as steps_lib
            t_sh, f_sh, opt_sh, _ = steps_lib.model_state_shardings(cfg, mesh)
            t_only, _ = rebranch.partition(
                jax.tree.map(lambda x: x, params))
            step, t2, opt2, _ = ckpt.restore(
                {str(tmp_path)!r}, t, opt, params,
                shardings=(t_sh, opt_sh))
        assert step == 3
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('OK')
    """)
    assert "OK" in out
