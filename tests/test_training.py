"""Training substrate: data determinism, optimizer, schedule, checkpoint
manager (atomic/keep-k/fingerprint/elastic), fault coordinator logic,
gradient compression, and an end-to-end smoke train run."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.checkpoint import manager as ckpt
from repro.core import rebranch
from repro.data import synthetic
from repro.distributed import fault
from repro.launch import steps as steps_lib
from repro.models import api
from repro.optim import compress, schedule


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

class TestData:
    CFG = synthetic.DataConfig(seed=3, vocab_size=64, seq_len=32,
                               global_batch=8)

    def test_deterministic(self):
        b1 = synthetic.markov_batch(self.CFG, step=7)
        b2 = synthetic.markov_batch(self.CFG, step=7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ(self):
        b1 = synthetic.markov_batch(self.CFG, step=7)
        b2 = synthetic.markov_batch(self.CFG, step=8)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))

    def test_shards_partition_the_batch(self):
        """Sharded reads are disjoint slices of the same global batch
        semantics (shard takeover needs no data-state migration)."""
        full = synthetic.markov_batch(self.CFG, step=3)
        s0 = synthetic.markov_batch(self.CFG, step=3, shard=0, num_shards=2)
        s1 = synthetic.markov_batch(self.CFG, step=3, shard=1, num_shards=2)
        assert s0["tokens"].shape[0] == s1["tokens"].shape[0] == 4
        assert full["tokens"].shape[0] == 8
        assert not np.array_equal(np.asarray(s0["tokens"]),
                                  np.asarray(s1["tokens"]))

    def test_labels_are_shifted_tokens(self):
        b = synthetic.markov_batch(self.CFG, step=0)
        np.testing.assert_array_equal(
            np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1]))

    def test_entropy_floor_positive(self):
        f = synthetic.entropy_floor(self.CFG)
        assert 0.5 < f < np.log(self.CFG.vocab_size)


# ---------------------------------------------------------------------------
# optimizer + schedule
# ---------------------------------------------------------------------------

class TestOptim:
    def test_adamw_reduces_quadratic(self):
        p = {"sram": {"w": jnp.array([3.0, -2.0])}}
        st = optim.init(p)
        cfg = optim.AdamWConfig(lr=0.2, weight_decay=0.0)
        for _ in range(100):
            g = jax.tree.map(lambda x: 2 * x, p)
            p, st, _ = optim.update(g, st, p, cfg)
        assert float(jnp.abs(p["sram"]["w"]).max()) < 0.1

    def test_none_leaves_passthrough(self):
        p = {"rom": {"w": None}, "sram": {"w": jnp.ones(3)}}
        st = optim.init(p)
        g = {"rom": {"w": None}, "sram": {"w": jnp.ones(3)}}
        p2, st2, _ = optim.update(g, st, p, optim.AdamWConfig())
        assert p2["rom"]["w"] is None
        assert p2["sram"]["w"].shape == (3,)

    def test_grad_clip(self):
        p = {"w": jnp.zeros(4)}
        st = optim.init(p)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, m = optim.update(g, st, p, optim.AdamWConfig(grad_clip=1.0))
        assert float(m["grad_norm"]) > 1e5   # reported pre-clip

    def test_cosine_schedule(self):
        lr0 = schedule.cosine_with_warmup(jnp.asarray(0), peak_lr=1.0,
                                          warmup_steps=10, total_steps=100)
        lr10 = schedule.cosine_with_warmup(jnp.asarray(10), peak_lr=1.0,
                                           warmup_steps=10, total_steps=100)
        lr100 = schedule.cosine_with_warmup(jnp.asarray(100), peak_lr=1.0,
                                            warmup_steps=10, total_steps=100)
        assert float(lr0) == 0.0
        assert float(lr10) == pytest.approx(1.0)
        assert float(lr100) == pytest.approx(0.1, abs=1e-3)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

class TestCompression:
    def test_error_feedback_unbiased_over_time(self):
        """Repeatedly compressing the same gradient with error feedback
        converges to it in the mean (EF-SGD property)."""
        g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 1e-3)
        err = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        n = 50
        for _ in range(n):
            q, scale, err = compress.quantize_with_feedback(g, err)
            acc = acc + q.astype(jnp.float32) * scale
        np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g),
                                   atol=float(jnp.abs(g).max()) * 0.02)

    def test_quantize_roundtrip_bounded(self):
        g = jnp.asarray(np.random.default_rng(1).normal(size=(32, 8)))
        q, scale, err = compress.quantize_with_feedback(
            g, jnp.zeros_like(g))
        deq = q.astype(jnp.float32) * scale
        assert float(jnp.abs(deq - g).max()) <= float(scale) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def _tiny_state():
    cfg = configs.get_smoke("gemma_2b")
    params = api.init(jax.random.PRNGKey(0), cfg)
    t, f = rebranch.partition(params)
    return cfg, params, t, f, optim.init(t)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cfg, params, t, f, opt = _tiny_state()
        ckpt.save(str(tmp_path), 5, t, opt, params)
        step, t2, opt2, _ = ckpt.restore(str(tmp_path), t, opt, params)
        assert step == 5
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(opt2["step"]) == int(opt["step"])

    def test_keep_k_gc(self, tmp_path):
        cfg, params, t, f, opt = _tiny_state()
        for s in [1, 2, 3, 4, 5]:
            ckpt.save(str(tmp_path), s, t, opt, params, keep=2)
        assert ckpt.latest_steps(str(tmp_path)) == [4, 5]

    def test_rom_fingerprint_guard(self, tmp_path):
        """Restoring against a different ROM image must refuse."""
        cfg, params, t, f, opt = _tiny_state()
        ckpt.save(str(tmp_path), 1, t, opt, params)
        params2 = api.init(jax.random.PRNGKey(99), cfg)   # different ROM
        with pytest.raises(ValueError, match="fingerprint"):
            ckpt.restore(str(tmp_path), t, opt, params2)

    def test_async_save(self, tmp_path):
        cfg, params, t, f, opt = _tiny_state()
        th = ckpt.save(str(tmp_path), 7, t, opt, params, async_=True)
        th.join()
        assert ckpt.latest_steps(str(tmp_path)) == [7]

    def test_atomic_no_tmp_left(self, tmp_path):
        cfg, params, t, f, opt = _tiny_state()
        ckpt.save(str(tmp_path), 3, t, opt, params)
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_gc_keep_zero_deletes_all(self, tmp_path):
        """keep=0 means keep NOTHING; ``steps[:-0]`` used to slice to []
        and silently keep everything."""
        cfg, params, t, f, opt = _tiny_state()
        for s in [1, 2, 3]:
            ckpt.save(str(tmp_path), s, t, opt, params)
        assert ckpt.latest_steps(str(tmp_path)) == [1, 2, 3]
        ckpt._gc(str(tmp_path), keep=0)
        assert ckpt.latest_steps(str(tmp_path)) == []
        ckpt.save(str(tmp_path), 4, t, opt, params, keep=0)
        assert ckpt.latest_steps(str(tmp_path)) == []   # save honors it too

    def test_latest_steps_skips_stray_dirs(self, tmp_path):
        """A stray ``step_*`` directory with a non-int suffix (an
        interrupted write renamed by hand) used to ValueError every
        restore/gc for the whole directory."""
        cfg, params, t, f, opt = _tiny_state()
        ckpt.save(str(tmp_path), 7, t, opt, params)
        os.makedirs(tmp_path / "step_broken")
        os.makedirs(tmp_path / "step_00000007_backup")
        assert ckpt.latest_steps(str(tmp_path)) == [7]
        step, t2, _, _ = ckpt.restore(str(tmp_path), t, opt, params)
        assert step == 7


# ---------------------------------------------------------------------------
# fault coordinator
# ---------------------------------------------------------------------------

class TestFault:
    CFG = fault.FaultConfig(heartbeat_timeout_s=10, min_data_parallel=2)

    def _hosts(self, n, spares=0):
        hs = [fault.HostState(i, last_heartbeat_s=100.0,
                              last_step_time_s=1.0) for i in range(n)]
        hs += [fault.HostState(n + i, last_heartbeat_s=100.0, is_spare=True)
               for i in range(spares)]
        return hs

    def test_dead_detection(self):
        hs = self._hosts(4)
        hs[2] = fault.HostState(2, last_heartbeat_s=80.0)
        assert fault.dead_hosts(hs, now_s=100.0, cfg=self.CFG) == [2]

    def test_straggler_detection(self):
        hs = self._hosts(8)
        hs[3] = fault.HostState(3, 100.0, last_step_time_s=5.0)
        assert fault.stragglers(hs, self.CFG) == [3]

    def test_spare_swap(self):
        hs = self._hosts(8, spares=2)
        plan = fault.plan_remesh(hs, failed=[1], data_axis=4,
                                 hosts_per_data_row=2, cfg=self.CFG)
        assert plan.action == "swap_spares"
        assert plan.new_data_axis == 4
        assert plan.replaced_by_spares == ((1, 8),)

    def test_shrink_to_power_of_two(self):
        hs = self._hosts(16)
        plan = fault.plan_remesh(hs, failed=[0, 1, 2], data_axis=8,
                                 hosts_per_data_row=2, cfg=self.CFG)
        assert plan.action == "shrink"
        assert plan.new_data_axis == 4           # 13 alive -> 6 rows -> 4
        assert len(plan.surviving_hosts) == 8

    def test_abort_below_min(self):
        hs = self._hosts(4)
        plan = fault.plan_remesh(hs, failed=[0, 1, 2], data_axis=2,
                                 hosts_per_data_row=2, cfg=self.CFG)
        assert plan.action == "abort"

    def test_shard_reassignment_total(self):
        m = fault.reassign_data_shards(16, surviving=[0, 3, 5])
        assert set(m.keys()) == set(range(16))
        assert set(m.values()) <= {0, 3, 5}


# ---------------------------------------------------------------------------
# end-to-end: smoke train run via the driver path
# ---------------------------------------------------------------------------

class TestEndToEnd:
    def test_train_loss_decreases_and_resumes(self, tmp_path):
        cfg = configs.get_smoke("gemma_2b")
        dcfg = synthetic.DataConfig(seed=0, vocab_size=cfg.vocab_size,
                                    seq_len=32, global_batch=4)
        params = api.init(jax.random.PRNGKey(0), cfg)
        t, f = rebranch.partition(params)
        opt = optim.init(t)
        step_fn = jax.jit(steps_lib.make_train_step(
            cfg, optim.AdamWConfig(lr=5e-3), loss_chunks=2))
        losses = []
        for s in range(12):
            batch = synthetic.markov_batch(dcfg, s)
            t, opt, m = step_fn(t, f, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]
        # checkpoint + restore mid-run == bit-identical continuation
        ckpt.save(str(tmp_path), 12, t, opt, params)
        _, t2, opt2, _ = ckpt.restore(str(tmp_path), t, opt, params)
        b = synthetic.markov_batch(dcfg, 12)
        t_a, _, ma = step_fn(t, f, opt, b)
        t_b, _, mb = step_fn(t2, f, opt2, b)
        assert float(ma["loss"]) == pytest.approx(float(mb["loss"]),
                                                  rel=1e-6)
