"""Regression tests for the ROM/SRAM pytree machinery.

partition/combine were only exercised on flat layer dicts; freeze_to_rom
on conv trees only implicitly through the transfer harness.  These pin the
contracts down on mixed dict/list/tuple nesting and on real conv pytrees.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rebranch
from repro.models import cnn

SPEC = rebranch.ReBranchSpec()


def _tree_equal(a, b):
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestPartitionCombine:
    def _mixed_tree(self):
        key = jax.random.PRNGKey(0)
        return {
            "blocks": [                                     # list of dicts
                rebranch.init_linear(jax.random.fold_in(key, 0), 16, 8, SPEC),
                {"inner": (                                 # tuple nesting
                    rebranch.init_linear(jax.random.fold_in(key, 1), 8, 8,
                                         SPEC),
                    {"sram": {"w": jnp.ones((4, 4))}},      # plain trainable
                )},
            ],
            "head": {"sram": {"w": jnp.zeros((8, 2)),
                              "b": jnp.zeros((2,))}},
            "scalar_meta": jnp.float32(1.0),                # bare leaf
        }

    def test_roundtrip_on_mixed_pytree(self):
        p = self._mixed_tree()
        t, f = rebranch.partition(p)
        _tree_equal(rebranch.combine(t, f), p)

    def test_partition_preserves_container_types(self):
        p = self._mixed_tree()
        t, f = rebranch.partition(p)
        assert isinstance(t["blocks"], list) and isinstance(f["blocks"], list)
        assert isinstance(t["blocks"][1]["inner"], tuple)
        assert isinstance(f["blocks"][1]["inner"], tuple)

    def test_rom_goes_frozen_sram_goes_trainable(self):
        p = self._mixed_tree()
        t, f = rebranch.partition(p)
        blk = p["blocks"][0]
        tb, fb = t["blocks"][0], f["blocks"][0]
        assert tb["rom"]["w_q"] is None and fb["rom"]["w_q"] is not None
        assert tb["sram"]["core"] is not None and fb["sram"]["core"] is None
        # the bare leaf outside any rom/ subtree is trainable
        assert t["scalar_meta"] is not None and f["scalar_meta"] is None
        del blk

    def test_namedtuple_nodes_are_rebuilt(self):
        import collections
        Pair = collections.namedtuple("Pair", ["a", "b"])
        p = {"rom": {"x": jnp.ones((2,))},
             "pair": Pair(jnp.zeros((3,)), jnp.ones((3,)))}
        t, f = rebranch.partition(p)
        assert isinstance(t["pair"], Pair) and isinstance(f["pair"], Pair)
        _tree_equal(rebranch.combine(t, f), p)

    def test_tuple_subclass_leaves_stay_leaves(self):
        """jax.sharding.PartitionSpec subclasses tuple but is a pytree LEAF;
        partition() must pass it through intact (regression: it used to be
        rebuilt as PartitionSpec(<generator>), breaking sharding trees)."""
        from jax.sharding import PartitionSpec as P
        tree = {"rom": {"w": P("model", None)}, "sram": {"w": P(None)}}
        t, f = rebranch.partition(tree)
        assert f["rom"]["w"] == P("model", None) and t["rom"]["w"] is None
        assert t["sram"]["w"] == P(None) and f["sram"]["w"] is None
        _tree_equal_structs = rebranch.combine(t, f)
        assert _tree_equal_structs["rom"]["w"] == P("model", None)

    def test_counts_are_disjoint_and_complete(self):
        p = self._mixed_tree()
        total = sum(x.size for x in jax.tree.leaves(p))
        assert (rebranch.trainable_count(p)
                + rebranch.frozen_count(p)) == total


class TestFreezeToRomConv:
    def _dense_cnn(self):
        """A mini conv tree the way pretraining leaves it: plain convs
        ({'sram': {'w': 4-D}}) mixed with BN and a dense head."""
        key = jax.random.PRNGKey(3)
        mk = lambda i, shape: {"sram": {"w": jax.random.normal(
            jax.random.fold_in(key, i), shape) / np.sqrt(np.prod(shape[:-1]))}}
        return {
            "convs": [mk(0, (3, 3, 3, 16)), mk(1, (1, 1, 16, 16))],
            "bns": [{"sram": {"scale": jnp.ones((16,)),
                              "bias": jnp.zeros((16,))}}],
            "fc": {"sram": {"w": jax.random.normal(
                jax.random.fold_in(key, 9), (16, 10)) * 0.01}},
        }

    def test_convs_become_rebranch_dense_stays(self):
        p = cnn.freeze_to_rom(self._dense_cnn(), jax.random.PRNGKey(1), SPEC)
        for conv in p["convs"]:
            assert "rom" in conv and conv["rom"]["w_q"].dtype == jnp.int8
            assert conv["rom"]["w_q"].ndim == 4
            assert "core" in conv["sram"]
        # dense head and BN untouched (stay pure SRAM)
        assert set(p["fc"].keys()) == {"sram"}
        assert set(p["bns"][0].keys()) == {"sram"}

    def test_frozen_conv_preserves_function(self):
        dense = self._dense_cnn()
        p = cnn.freeze_to_rom(dense, jax.random.PRNGKey(1), SPEC)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8, 3))
        want = jax.lax.conv_general_dilated(
            x, dense["convs"][0]["sram"]["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        got = cnn.apply_conv(p["convs"][0], x, SPEC)
        # zero-init core: output is the int8-quantised trunk alone
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0.06, atol=0.06)

    def test_partition_roundtrip_on_frozen_conv_tree(self):
        p = cnn.freeze_to_rom(self._dense_cnn(), jax.random.PRNGKey(1), SPEC)
        t, f = rebranch.partition(p)
        _tree_equal(rebranch.combine(t, f), p)
        # the ROM trunk dominates the parameter bytes (paper's premise)
        assert rebranch.frozen_count(p) > rebranch.trainable_count(p)
