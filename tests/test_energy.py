"""Cost-model tests: the benchmark harness must reproduce the paper's
headline claims from the real model statistics (see EXPERIMENTS.md for
which constants are Table-I verbatim vs calibrated)."""

import sys
import os

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import netstats
from repro.core import energy


@pytest.fixture(scope="module")
def stats():
    return netstats.paper_net_stats()


class TestPaperClaims:
    def test_model_sizes_match_paper(self, stats):
        assert 40e6 < stats["darknet19"].params < 52e6      # "46 M weights"
        assert 9e6 < stats["tiny_yolo"].params < 16e6       # "11.3 M"

    @pytest.mark.parametrize("name,paper,tol", [
        ("resnet18", 4.8, 0.15), ("tiny_yolo", 10.2, 0.15),
        ("darknet19", 14.8, 0.15),
    ])
    def test_energy_efficiency_ratios(self, stats, name, paper, tol):
        ours = energy.efficiency_ratio(stats[name])
        assert abs(ours - paper) / paper < tol, (name, ours, paper)

    def test_area_ratio_yolo(self, stats):
        ours = energy.area_ratio(stats["darknet19"])
        assert abs(ours - 9.7) / 9.7 < 0.15                 # paper 9.7x

    def test_area_ratio_tiny_yolo_footnote_basis(self, stats):
        ours = (energy.all_sram_area(stats["tiny_yolo"])
                / energy.yoloc_area(stats["darknet19"]))
        assert abs(ours - 2.4) / 2.4 < 0.15                 # paper 2.4x

    def test_chiplet_comparison(self, stats):
        ns = stats["darknet19"]
        ratio = (energy.chiplet_energy(ns)["total"]
                 / energy.yoloc_energy(ns)["total"])
        assert 0.9 < ratio < 1.15                            # paper ~1.02x

    def test_latency_overhead(self, stats):
        lat = energy.yoloc_latency(stats["darknet19"])
        assert abs(lat["overhead_frac"] - 0.08) < 0.02       # paper 8%

    def test_yoloc_has_zero_dram_weight_traffic(self, stats):
        for ns in stats.values():
            assert energy.yoloc_energy(ns)["dram"] == 0.0

    def test_rom_density_premise(self):
        cm = energy.DEFAULT_COST
        assert cm.rom_density_mb_mm2 / cm.sram_density_mb_mm2 == 19.0

    def test_macro_table(self):
        from benchmarks import table1_macro
        for name, ours, paper in table1_macro.rows():
            if paper == 0:
                assert ours == 0
            else:
                assert abs(ours - paper) / abs(paper) < 0.16, (name, ours)


class TestCostModelProperties:
    def test_efficiency_monotone_in_reload(self, stats):
        import dataclasses
        ns = stats["darknet19"]
        lo = dataclasses.replace(ns, reload_factor=1.0)
        hi = dataclasses.replace(ns, reload_factor=8.0)
        assert (energy.efficiency_ratio(hi) > energy.efficiency_ratio(lo))

    def test_area_scales_with_params(self, stats):
        import dataclasses
        ns = stats["resnet18"]
        big = dataclasses.replace(ns, params=ns.params * 2)
        assert energy.yoloc_area(big) > 1.9 * energy.yoloc_area(ns)

    def test_branch_fraction_effect(self, stats):
        import dataclasses
        ns = stats["resnet18"]
        fat = dataclasses.replace(ns, branch_fraction=0.25)   # D*U=4
        assert energy.yoloc_area(fat) > energy.yoloc_area(ns)
