"""TrunkEngine registry + repro.deploy.compile_model.

Covers the API-redesign contract:
  * strict resolution — unknown ``trunk_impl`` raises with the registered
    set (no silent int8_native fallback), from linears AND convs;
  * registration/override semantics and capability gating;
  * compile_model parity vs the old free-function path for all three
    stock engines on a transformer and a CNN config (bit-identical);
  * per-layer engine / ROM-vs-SRAM override mapping;
  * BN + leaky-ReLU folded into the conv trunk epilogue vs the unfused
    path on a DarkNet-19 block.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import deploy, engine
from repro.core import cim as cim_lib
from repro.core import rebranch
from repro.engine import base as engine_base
from repro.models import api, cnn
from repro.models.config import ArchConfig, spec_for

ENGINES = ["int8_native", "dequant", "pallas"]


def _lm_cfg(**kw):
    """A tiny dense transformer that runs a real CPU forward."""
    return ArchConfig(name="t_test", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=128, remat=False, dtype="float32", **kw)


def _cnn_cfg(**kw):
    return cnn.CNNConfig(name="vgg8", num_classes=13, input_size=16, **kw)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

class _ToyEngine(engine.TrunkEngine):
    name = "toy"
    capabilities = engine.EngineCapabilities(fidelity_modes=("ideal",))

    def matmul(self, cfg, x, w_q, w_scale, *, out_axes=None):
        return (x @ w_q.astype(x.dtype)) * w_scale.astype(x.dtype)


class TestRegistry:
    def test_stock_engines_registered(self):
        assert set(ENGINES) <= set(engine.registered_names())

    def test_unknown_name_lists_registered(self):
        with pytest.raises(ValueError) as e:
            engine.get("does_not_exist")
        for name in ENGINES:
            assert name in str(e.value)

    def test_duplicate_registration_needs_override(self):
        engine.register("toy_dup", _ToyEngine())
        try:
            with pytest.raises(ValueError, match="already registered"):
                engine.register("toy_dup", _ToyEngine())
            replacement = _ToyEngine()
            engine.register("toy_dup", replacement, override=True)
            assert engine.get("toy_dup") is replacement
        finally:
            engine.unregister("toy_dup")

    def test_capability_gating_fidelity_mode(self):
        """Requesting bitserial from an engine that lacks it fails loudly."""
        engine.register("toy_ideal_only", _ToyEngine())
        try:
            spec = rebranch.ReBranchSpec(
                trunk_impl="toy_ideal_only",
                cim=cim_lib.CiMConfig(mode="bitserial"))
            with pytest.raises(ValueError, match="bitserial"):
                engine.resolve(spec)
            # the supported mode resolves fine
            ok = dataclasses.replace(spec,
                                     cim=cim_lib.CiMConfig(mode="ideal"))
            assert engine.resolve(ok).name == "toy"
        finally:
            engine.unregister("toy_ideal_only")

    def test_dequant_is_fidelity_agnostic(self):
        spec = rebranch.ReBranchSpec(trunk_impl="dequant",
                                     cim=cim_lib.CiMConfig(mode="bitserial"))
        assert engine.resolve(spec).name == "dequant"

    def test_custom_engine_runs_in_a_layer(self):
        """A user-registered backend plugs into apply_linear untouched."""
        engine.register("toy_linear", _ToyEngine())
        try:
            spec = rebranch.ReBranchSpec(
                trunk_impl="toy_linear",
                cim=cim_lib.CiMConfig(mode="ideal"))
            p = rebranch.init_linear(jax.random.PRNGKey(0), 16, 8, spec)
            x = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
            y = rebranch.apply_linear(p, x, spec)
            assert y.shape == (2, 8)
        finally:
            engine.unregister("toy_linear")


# ---------------------------------------------------------------------------
# strict resolution from the layers (the old silent-fallback bug)
# ---------------------------------------------------------------------------

class TestStrictResolution:
    def test_linear_unknown_impl_raises(self):
        spec = rebranch.ReBranchSpec(trunk_impl="int8_natve")   # typo
        p = rebranch.init_linear(jax.random.PRNGKey(0), 16, 8, spec)
        x = jnp.ones((2, 16))
        with pytest.raises(ValueError, match="int8_natve"):
            rebranch.apply_linear(p, x, spec)

    def test_conv_unknown_impl_raises(self):
        spec = rebranch.ReBranchSpec(trunk_impl="palas")        # typo
        p = cnn.init_conv(jax.random.PRNGKey(0), 3, 8, 8, spec)
        x = jnp.ones((1, 6, 6, 8))
        with pytest.raises(ValueError) as e:
            cnn.apply_conv(p, x, spec)
        assert "palas" in str(e.value)
        for name in ENGINES:                # message lists the valid set
            assert name in str(e.value)

    def test_compile_model_unknown_engine_raises(self):
        with pytest.raises(ValueError, match="registered engines"):
            deploy.compile_model(_lm_cfg(), engine="nope")

    def test_compile_model_unknown_override_engine_raises(self):
        with pytest.raises(ValueError, match="registered engines"):
            deploy.compile_model(_cnn_cfg(),
                                 layer_overrides={"convs.0":
                                                  {"engine": "nope"}})

    def test_compile_model_unknown_override_key_raises(self):
        with pytest.raises(ValueError, match="unknown keys"):
            deploy.compile_model(_cnn_cfg(),
                                 layer_overrides={"convs.0":
                                                  {"engin": "pallas"}})


# ---------------------------------------------------------------------------
# compile_model parity vs the old free-function path
# ---------------------------------------------------------------------------

class TestCompileModelParity:
    @pytest.mark.parametrize("impl", ENGINES)
    def test_transformer_bit_identical(self, impl):
        cfg = _lm_cfg(rebranch=rebranch.ReBranchSpec(trunk_impl=impl))
        key = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 8), 0, cfg.vocab_size)}
        params_old = api.init(key, cfg)
        logits_old = api.forward(params_old, batch, cfg)

        model = deploy.compile_model(cfg)
        params_new = model.init(key)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), params_old, params_new)
        np.testing.assert_array_equal(np.asarray(logits_old),
                                      np.asarray(model.forward(params_new,
                                                               batch)))

    @pytest.mark.parametrize("impl", ENGINES)
    def test_cnn_bit_identical(self, impl):
        cfg = _cnn_cfg(rebranch=rebranch.ReBranchSpec(trunk_impl=impl))
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
        init_fn, apply_fn = cnn.MODEL_REGISTRY[cfg.name]
        params_old = init_fn(key, cfg)
        out_old = apply_fn(params_old, x, cfg)

        model = deploy.compile_model(cfg)
        params_new = model.init(key)
        np.testing.assert_array_equal(np.asarray(out_old),
                                      np.asarray(model.forward(params_new,
                                                               x)))

    def test_engine_kwarg_overrides_config(self):
        cfg = _cnn_cfg()                       # default int8_native
        model = deploy.compile_model(cfg, engine="dequant")
        assert model.engine.name == "dequant"
        assert model.cfg.rebranch.trunk_impl == "dequant"

    def test_serve_surface(self):
        """prefill/decode_step/init_cache round-trip through the bundle."""
        cfg = _lm_cfg()
        model = deploy.compile_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(2, 8, dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                  cfg.vocab_size)
        logits, cache = model.prefill(params, {"tokens": toks}, cache)
        assert logits.shape == (2, 1, cfg.vocab_size)
        logits2, _ = model.decode_step(params, toks[:, :1], cache)
        assert logits2.shape == (2, 1, cfg.vocab_size)

    def test_cnn_has_no_serve_surface(self):
        model = deploy.compile_model(_cnn_cfg())
        with pytest.raises(NotImplementedError):
            model.init_cache(2, 8)


# ---------------------------------------------------------------------------
# per-layer engine / ROM-vs-SRAM mapping
# ---------------------------------------------------------------------------

class TestLayerOverrides:
    def test_cnn_first_layer_sram(self):
        """Fig. 12-style mapping: the stem conv stays SRAM-trainable while
        the rest of the trunk freezes into ROM."""
        model = deploy.compile_model(
            _cnn_cfg(), layer_overrides={"convs.0": {"memory": "sram"}})
        params = model.init(jax.random.PRNGKey(0))
        assert "rom" not in params["convs"][0]          # plain trainable
        assert "rom" in params["convs"][1]              # frozen trunk
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
        assert model.forward(params, x).shape == (1, 13)
        # the SRAM layer's weights are in the trainable partition
        t, f = rebranch.partition(params)
        assert t["convs"][0]["sram"]["w"] is not None

    def test_cnn_per_layer_engine(self):
        model = deploy.compile_model(
            _cnn_cfg(), layer_overrides={"convs.1": {"engine": "dequant"}})
        assert model.layer_spec("convs.1").trunk_impl == "dequant"
        assert model.layer_spec("convs.0").trunk_impl == "int8_native"
        params = model.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
        assert bool(jnp.all(jnp.isfinite(model.forward(params, x))))

    def test_lm_head_sram_override(self):
        """The readout stays a plain trainable linear while blocks freeze."""
        cfg = _lm_cfg()
        model = deploy.compile_model(
            cfg, layer_overrides={"lm_head": {"memory": "sram"}})
        params = model.init(jax.random.PRNGKey(0))
        assert "rom" not in params["lm_head"]
        assert set(params["lm_head"]) == {"sram"}
        batch = {"tokens": jnp.zeros((1, 4), jnp.int32)}
        assert model.forward(params, batch).shape == (1, 4, cfg.vocab_size)

    def test_blocks_cim_mode_override(self):
        """Dropping only the blocks to per_subarray fidelity changes the
        forward; the unmapped config does not."""
        cfg = _lm_cfg()
        base = deploy.compile_model(cfg)
        params = base.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.arange(8, dtype=jnp.int32).reshape(1, 8)}
        y0 = base.forward(params, batch)
        mapped = deploy.compile_model(
            cfg, layer_overrides={"blocks": {"cim": "per_subarray"}})
        y1 = mapped.forward(params, batch)
        assert float(jnp.max(jnp.abs(y0 - y1))) > 0
        again = deploy.compile_model(cfg)
        np.testing.assert_array_equal(np.asarray(y0),
                                      np.asarray(again.forward(params,
                                                               batch)))

    def test_spec_accepted_verbatim(self):
        spec = rebranch.ReBranchSpec(enabled=False)
        model = deploy.compile_model(_cnn_cfg(),
                                     layer_overrides={"convs.2": spec})
        assert model.layer_spec("convs.2") is spec

    def test_unknown_site_raises(self):
        """Typo'd / unwired site names fail loudly (no silent no-op)."""
        with pytest.raises(ValueError, match="conv.0"):
            deploy.compile_model(_cnn_cfg(),
                                 layer_overrides={"conv.0":        # typo
                                                  {"memory": "sram"}})
        with pytest.raises(ValueError, match="not wired"):
            deploy.compile_model(_lm_cfg(),
                                 layer_overrides={"pred": {"memory": "sram"}})

    def test_ssm_family_sites_wired(self):
        """PR 5: ssm/hybrid families now expose per-site overrides."""
        cfg = ArchConfig(name="s_test", family="ssm", num_layers=1,
                         d_model=16, ssm_state=4, vocab_size=32)
        assert {"blocks", "blocks.in_proj", "blocks.out_proj",
                "lm_head"} <= deploy.valid_sites(cfg)
        model = deploy.compile_model(
            cfg, layer_overrides={"lm_head": {"memory": "sram"}})
        p = model.init(jax.random.PRNGKey(0))
        assert "rom" not in p["lm_head"]
        deploy.compile_model(cfg)           # no overrides: fine

    def test_valid_sites_enumeration(self):
        assert deploy.valid_sites(_cnn_cfg()) == {
            f"convs.{i}" for i in range(6)} | {"convs"}
        rs = deploy.valid_sites(cnn.CNNConfig(name="resnet18"))
        assert "stem" in rs and "stages.1.0.proj" in rs
        assert "stages.1" in rs                 # ancestor prefixes valid
        assert "stages.0.0.proj" not in rs      # stage 0 has no projection
        assert deploy.valid_sites(_lm_cfg()) == {
            "blocks", "blocks.attn", "blocks.mlp", "lm_head"}

    def test_engine_instance_conflict_raises(self):
        """Passing an instance whose name is taken by a DIFFERENT engine
        must not silently swap the registry entry under other models."""
        stock = engine.get("dequant")

        class _Impostor(engine.TrunkEngine):
            name = "dequant"

        with pytest.raises(ValueError, match="conflicts"):
            deploy.compile_model(_cnn_cfg(), engine=_Impostor())
        assert engine.get("dequant") is stock   # registry untouched
        # the registered instance itself is accepted
        assert deploy.compile_model(_cnn_cfg(),
                                    engine=stock).engine is stock

    def test_overrides_are_jit_static_safe(self):
        cfg = deploy.compile_model(
            _cnn_cfg(), layer_overrides={"convs.0": {"memory": "sram"}}).cfg
        hash(cfg)                                       # hashable (static)
        assert spec_for(cfg, "convs.0").enabled is False
        assert spec_for(cfg, "convs.3") is cfg.rebranch


# ---------------------------------------------------------------------------
# BN + leaky-ReLU folded into the conv trunk epilogue
# ---------------------------------------------------------------------------

class TestEpilogueFusion:
    @pytest.mark.parametrize("impl", ENGINES)
    def test_darknet_block_parity(self, impl):
        """conv+BN+leaky on a DarkNet-19 block: fused epilogue ==
        unfused (inference-style BN), per engine."""
        spec = rebranch.ReBranchSpec(trunk_impl=impl)
        key = jax.random.PRNGKey(0)
        c, k = cnn.DARKNET19[2]                        # (64, 3) block
        p = cnn.init_conv(key, k, 32, c, spec)
        p["sram"]["core"] = jax.random.normal(
            jax.random.PRNGKey(2), p["sram"]["core"].shape) * 0.05
        bn = cnn._bn_init(c)
        bn["sram"]["mean"] = jax.random.normal(jax.random.PRNGKey(3), (c,))
        bn["sram"]["var"] = jax.nn.softplus(
            jax.random.normal(jax.random.PRNGKey(4), (c,)))
        bn["sram"]["scale"] = 1.0 + 0.1 * jax.random.normal(
            jax.random.PRNGKey(5), (c,))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 32))

        unfused = cnn._leaky(cnn._bn_apply(bn, cnn.apply_conv(p, x, spec)))
        fused = cnn.apply_conv(p, x, spec,
                               epilogue=cnn.bn_epilogue(bn, "leaky_relu"))
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   rtol=1e-4, atol=1e-4)

    def test_resnet_model_fused_flag(self):
        """ResNet-18 honours fuse_bn_act too (act fuses only where it
        legally follows the conv; bn2/proj stay affine-only)."""
        cfg = cnn.CNNConfig(name="resnet18", num_classes=7, input_size=16)
        params = cnn.init_resnet18(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
        y0 = cnn.apply_resnet18(params, x, cfg)
        y1 = cnn.apply_resnet18(params, x,
                                dataclasses.replace(cfg, fuse_bn_act=True))
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                                   rtol=1e-4, atol=1e-4)

    def test_darknet_model_fused_flag(self):
        cfg = cnn.CNNConfig(name="tiny_yolo", input_size=64)
        params = cnn.init_tiny_yolo(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
        y0 = cnn.apply_darknet(params, x, cfg)
        y1 = cnn.apply_darknet(params, x,
                               dataclasses.replace(cfg, fuse_bn_act=True))
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                                   rtol=1e-4, atol=1e-4)

    def test_epilogue_gradients_flow_to_branch_and_bn_bias(self):
        """The fused path keeps the branch core (and the BN bias riding
        the epilogue) trainable."""
        spec = rebranch.ReBranchSpec()
        p = cnn.init_conv(jax.random.PRNGKey(0), 3, 16, 16, spec)
        bn = cnn._bn_init(16)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 6, 16))
        t, f = rebranch.partition({"conv": p, "bn": bn})

        def loss(t):
            m = rebranch.combine(t, f)
            y = cnn.apply_conv(m["conv"], x, spec,
                               epilogue=cnn.bn_epilogue(m["bn"],
                                                        "leaky_relu"))
            return jnp.sum(y ** 2)

        g = jax.grad(loss)(t)
        assert float(jnp.sum(jnp.abs(g["conv"]["sram"]["core"]))) > 0
        assert float(jnp.sum(jnp.abs(g["bn"]["sram"]["bias"]))) > 0

    def test_engine_without_epilogue_support_falls_back(self):
        """An engine with capabilities.epilogue=False never receives one;
        the layer applies BN+act itself and the result still matches."""
        class _NoEpConv(engine.TrunkEngine):
            name = "toy_noep"
            capabilities = engine.EngineCapabilities(epilogue=False)

            def conv(self, cfg, x, w_q, w_scale, *, stride=1,
                     padding="SAME", epilogue=None):
                assert epilogue is None, "layer leaked an epilogue"
                return rebranch.trunk_conv(cfg, stride, padding,
                                           x, w_q, w_scale)

        engine.register("toy_noep", _NoEpConv())
        try:
            spec = rebranch.ReBranchSpec(trunk_impl="toy_noep")
            p = cnn.init_conv(jax.random.PRNGKey(0), 3, 16, 16, spec)
            p["sram"]["core"] = jax.random.normal(
                jax.random.PRNGKey(2), p["sram"]["core"].shape) * 0.05
            bn = cnn._bn_init(16)
            bn["sram"]["mean"] = jax.random.normal(jax.random.PRNGKey(3),
                                                   (16,))
            x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 6, 16))
            fused = cnn.apply_conv(p, x, spec,
                                   epilogue=cnn.bn_epilogue(bn,
                                                            "leaky_relu"))
            ref_spec = rebranch.ReBranchSpec()      # int8_native reference
            want = cnn._leaky(cnn._bn_apply(bn,
                                            cnn.apply_conv(p, x, ref_spec)))
            np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)
        finally:
            engine.unregister("toy_noep")

    def test_epilogue_on_plain_conv(self):
        """enabled=False layers honour the epilogue too (pred head)."""
        spec = rebranch.ReBranchSpec(enabled=False)
        p = cnn.init_conv(jax.random.PRNGKey(0), 1, 8, 8, spec)
        bn = cnn._bn_init(8)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 4, 8))
        fused = cnn.apply_conv(p, x, spec, epilogue=cnn.bn_epilogue(bn,
                                                                    "relu"))
        unfused = jax.nn.relu(cnn._bn_apply(bn, cnn.apply_conv(p, x, spec)))
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine base helpers
# ---------------------------------------------------------------------------

class TestEpilogueHelpers:
    def test_finish_none_passthrough(self):
        y = jnp.ones((4,))
        assert engine_base.finish(y, None) is y
        assert engine_base.activate(y, None) is y

    def test_unknown_activation_raises(self):
        ep = engine_base.ConvEpilogue(act="gelu")
        with pytest.raises(ValueError, match="gelu"):
            engine_base.activate(jnp.ones((2,)), ep)

    def test_without_act(self):
        ep = engine_base.ConvEpilogue(scale=jnp.ones((2,)), act="relu")
        assert ep.without_act().act is None
        assert ep.without_act().scale is ep.scale
