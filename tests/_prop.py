"""Property-test shim: real hypothesis when installed, else a thin fallback.

The tier-1 suite must collect (and pass) on a bare interpreter, but the
property tests are worth keeping when `hypothesis` is available
(``pip install -r requirements-dev.txt``).  Import from here instead of
from hypothesis:

    from _prop import given, settings, st

The fallback `given` runs the test body on a fixed number of seeded
pseudo-random draws per strategy — deterministic, no shrinking, but the
same shape/edge-case sweep intent.  Only the strategies this repo uses
(`st.integers`) are implemented; extend as needed.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 8

    class _Integers:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def draw(self, rng: random.Random) -> int:
            # hit the bounds first (the usual property-test edge cases),
            # then sample the interior
            return rng.choice((self.lo, self.hi, rng.randint(self.lo, self.hi)))

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Integers:
            return _Integers(min_value, max_value)

    st = _Strategies()

    def settings(**_kwargs):
        """No-op decorator (max_examples/deadline are hypothesis knobs)."""
        return lambda f: f

    def given(**strategies):
        def deco(f):
            def wrapper(*args):          # (self,) for methods, () for funcs
                seed = zlib.crc32(f.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(_FALLBACK_EXAMPLES):
                    draws = {k: s.draw(rng) for k, s in strategies.items()}
                    f(*args, **draws)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__qualname__ = f.__qualname__
            return wrapper
        return deco
