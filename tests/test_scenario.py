"""Scenario subsystem: branch hot-swap over one resident ROM trunk.

The load-bearing invariants (ISSUE 8):
  * a hot-swapped branch is BIT-identical to a freshly compiled
    single-scenario cell — for every CNN trunk and for LM decode
    through the continuous-batching scheduler;
  * a swap is a FIFO barrier: in-flight requests finish entirely under
    the scenario they were admitted with, requests behind the barrier
    decode entirely under the new one (mixed-scenario isolation);
  * the ScenarioStore's device cache evicts in LRU order;
  * a branch can never cross a placement boundary: plan-fingerprint
    mismatches are rejected at register/restore/implant time, and
    template mismatches raise geometry-style errors naming the
    expected vs found structure (mirrors cache_geometry / PR 7).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import deploy, scenario, serve
from repro.checkpoint import manager as ckpt
from repro.core import rebranch
from repro.core.rebranch import ReBranchSpec
from repro.models import cnn
from repro.plan import PlacementPlan
from repro.scenario import ScenarioStore
from repro.serve.pool import SlotPool
from repro.serve.scheduler import ContinuousBatcher

LM_ID = "gemma-2b-smoke"
MAX_LEN = 48
CNN_TRUNKS = ("vgg8", "resnet18", "darknet19", "tiny_yolo")


def _copy(tree):
    return jax.tree.map(jnp.array, tree)


def _perturb(branch, salt=1):
    """A distinct-but-compatible scenario branch (no training needed)."""
    def f(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x + jnp.asarray(0.01 * salt, x.dtype)
        return x
    return jax.tree.map(f, branch)


@pytest.fixture(scope="module")
def vgg_cell():
    """Small vgg8 deployment with an explicit plan (cheap to compile)."""
    cfg = cnn.CNNConfig(name="vgg8", input_size=16)
    plan = PlacementPlan.from_config(cfg)
    model = deploy.compile_model(cfg, plan=plan)
    params = model.init(jax.random.PRNGKey(0))
    return model, plan, params


@pytest.fixture(scope="module")
def lm_cell():
    model, plan = serve.compile_entry(LM_ID)
    params = model.init(jax.random.PRNGKey(0))
    return model, plan, params


def _solo_decode(model, params, prompt, n_new):
    cache = model.init_cache(1, MAX_LEN, dtype=jnp.float32)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(np.asarray(prompt)[None])}, cache)
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    for _ in range(n_new - 1):
        logits, cache = jax.jit(model.decode_step)(
            params, jnp.asarray([[tok]], jnp.int32), cache)
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# branch extraction / validation / fingerprints
# ---------------------------------------------------------------------------

class TestBranch:
    def test_split_combine_roundtrip(self, vgg_cell):
        model, _, params = vgg_cell
        branch, trunk = scenario.split_params(params)
        rebuilt = rebranch.combine(branch, trunk)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rebuilt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_plan_fingerprint_stable_and_discriminating(self, vgg_cell):
        _, plan, _ = vgg_cell
        fp = scenario.plan_fingerprint(plan)
        assert fp == scenario.plan_fingerprint(plan)   # process-stable
        assert scenario.plan_fingerprint(None) == "no-plan"
        assert fp != "no-plan"
        other = PlacementPlan.from_config(
            cnn.CNNConfig(name="vgg8", input_size=16,
                          rebranch=ReBranchSpec(d_ratio=8)))
        assert scenario.plan_fingerprint(other) != fp

    def test_validate_missing_tensors(self, vgg_cell):
        model, _, _ = vgg_cell
        # a trunk-only deployment's branch lacks the adapter tensors
        bare = cnn.CNNConfig(name="vgg8", input_size=16,
                             rebranch=ReBranchSpec(branch_enabled=False))
        bare_model = deploy.compile_model(bare)
        small = rebranch.partition(
            bare_model.init(jax.random.PRNGKey(1)))[0]
        with pytest.raises(ValueError, match="missing tensors"):
            scenario.validate_branch(
                small, scenario.branch_template(model))
        # and the converse direction reports the extras
        full = rebranch.partition(model.init(jax.random.PRNGKey(1)))[0]
        with pytest.raises(ValueError, match="unexpected tensors"):
            scenario.validate_branch(
                full, scenario.branch_template(bare_model))

    def test_validate_shape_mismatch_names_both(self, vgg_cell):
        model, _, params = vgg_cell
        branch = rebranch.partition(params)[0]
        leaves, treedef = jax.tree_util.tree_flatten(branch)
        leaves[0] = jnp.zeros((3, 3), leaves[0].dtype)
        bad = jax.tree_util.tree_unflatten(treedef, leaves)
        with pytest.raises(ValueError, match=r"\(3, 3\)"):
            scenario.validate_branch(bad, scenario.branch_template(model))

    def test_extract_implant_roundtrip(self, vgg_cell):
        model, plan, params = vgg_cell
        p2 = _copy(params)
        bundle = scenario.extract(
            model, rebranch.combine(_perturb(scenario.split_params(p2)[0]),
                                    scenario.split_params(p2)[1]), plan)
        out = scenario.implant(model, _copy(params), bundle, plan,
                               donate=False)
        ref = rebranch.combine(bundle.params,
                               scenario.split_params(params)[1])
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, 16, 16, 3)), jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(jax.jit(model.forward)(out, x)),
            np.asarray(jax.jit(model.forward)(ref, x)))

    def test_implant_rejects_plan_mismatch(self, vgg_cell):
        model, plan, params = vgg_cell
        bundle = scenario.extract(model, params, plan)
        with pytest.raises(ValueError, match="placement plan"):
            scenario.implant(model, _copy(params), bundle, None)

    def test_implant_rejects_model_mismatch(self, vgg_cell):
        model, plan, params = vgg_cell
        bundle = scenario.extract(model, params, plan)
        wrong = scenario.BranchBundle(model="resnet18",
                                      plan_fp=bundle.plan_fp,
                                      params=bundle.params)
        with pytest.raises(ValueError, match="resnet18"):
            scenario.implant(model, _copy(params), wrong, plan)


# ---------------------------------------------------------------------------
# hot-swap bit-parity: every CNN trunk
# ---------------------------------------------------------------------------

class TestSwapParity:
    @pytest.mark.parametrize("name", CNN_TRUNKS)
    def test_swap_matches_freshly_compiled_cell(self, name):
        """The headline invariant: swapping branch B onto a resident
        trunk gives EXACTLY the bits of compiling a new cell and
        combining B with the trunk from scratch."""
        cfg = cnn.CNNConfig(name=name, input_size=32)
        model = deploy.compile_model(cfg)
        pA = model.init(jax.random.PRNGKey(0))
        brB = _perturb(scenario.split_params(pA)[0], salt=3)
        swapped = scenario.swap_params(_copy(pA), brB, donate=False)
        fresh_model = deploy.compile_model(cfg)      # new cell, same cfg
        fresh = rebranch.combine(brB, scenario.split_params(pA)[1])
        x = jnp.asarray(np.random.default_rng(1).normal(
            size=(2, 32, 32, 3)), jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(jax.jit(model.forward)(swapped, x)),
            np.asarray(jax.jit(fresh_model.forward)(fresh, x)),
            err_msg=f"{name}: hot-swap diverged from fresh cell")

    def test_swap_leaves_trunk_aliased(self, vgg_cell):
        """The trunk (ROM) tensors pass through the swap untouched."""
        model, _, params = vgg_cell
        p = _copy(params)
        out = scenario.swap_params(
            p, _perturb(scenario.split_params(params)[0], salt=2),
            donate=False)
        _, trunk_out = scenario.split_params(out)
        _, trunk_in = scenario.split_params(params)
        for a, b in zip(jax.tree.leaves(trunk_in),
                        jax.tree.leaves(trunk_out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# ScenarioStore: strict names + LRU device cache
# ---------------------------------------------------------------------------

class TestStore:
    def _store(self, vgg_cell, capacity=2, n=3):
        model, plan, params = vgg_cell
        store = ScenarioStore(model, plan, capacity=capacity)
        base = scenario.split_params(params)[0]
        for i in range(n):
            store.register(f"s{i}", branch=_perturb(base, salt=i + 1))
        return store

    def test_lru_eviction_order(self, vgg_cell):
        store = self._store(vgg_cell, capacity=2, n=3)
        store.get("s0")
        store.get("s1")
        assert store.cached() == ["s0", "s1"]
        store.get("s2")                      # evicts s0 (LRU)
        assert store.cached() == ["s1", "s2"]
        store.get("s1")                      # hit: s1 becomes MRU
        store.get("s0")                      # reload: evicts s2, not s1
        assert store.cached() == ["s1", "s0"]
        assert store.evicted == ["s0", "s2"]
        assert store.hits == 1 and store.misses == 4

    def test_unknown_scenario_lists_registered(self, vgg_cell):
        store = self._store(vgg_cell)
        with pytest.raises(KeyError, match=r"s0.*s1.*s2"):
            store.get("nope")

    def test_duplicate_register_needs_override(self, vgg_cell):
        store = self._store(vgg_cell)
        base = scenario.split_params(vgg_cell[2])[0]
        with pytest.raises(ValueError, match="already registered"):
            store.register("s0", branch=base)
        store.register("s0", branch=base, override=True)

    def test_bundle_plan_mismatch_rejected(self, vgg_cell):
        model, plan, params = vgg_cell
        store = ScenarioStore(model, plan)
        bundle = scenario.BranchBundle(
            model=model.cfg.name, plan_fp="deadbeefdeadbeef",
            params=scenario.split_params(params)[0])
        with pytest.raises(ValueError, match="mismatched placement"):
            store.register("x", bundle=bundle)

    def test_exactly_one_source(self, vgg_cell):
        model, plan, params = vgg_cell
        store = ScenarioStore(model, plan)
        with pytest.raises(ValueError, match="exactly one"):
            store.register("x")


# ---------------------------------------------------------------------------
# branch-only checkpoints
# ---------------------------------------------------------------------------

class TestBranchCheckpoint:
    def test_roundtrip_bitwise(self, vgg_cell, tmp_path):
        model, plan, params = vgg_cell
        branch = _perturb(scenario.split_params(params)[0], salt=5)
        ckpt.save_branch(str(tmp_path), "night", branch,
                         model_name=model.cfg.name, plan=plan,
                         extra={"acc": 0.5})
        assert ckpt.branch_scenarios(str(tmp_path)) == ["night"]
        got = ckpt.restore_branch(str(tmp_path), "night",
                                  scenario.branch_template(model),
                                  plan=plan, model_name=model.cfg.name)
        for a, b in zip(jax.tree.leaves(branch), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_plan_fingerprint_mismatch_refused(self, vgg_cell, tmp_path):
        model, plan, params = vgg_cell
        branch = scenario.split_params(params)[0]
        ckpt.save_branch(str(tmp_path), "day", branch,
                         model_name=model.cfg.name, plan=plan)
        with pytest.raises(ValueError, match="mismatched placement"):
            ckpt.restore_branch(str(tmp_path), "day",
                                scenario.branch_template(model), plan=None)

    def test_model_mismatch_refused(self, vgg_cell, tmp_path):
        model, plan, params = vgg_cell
        ckpt.save_branch(str(tmp_path), "day",
                         scenario.split_params(params)[0],
                         model_name=model.cfg.name, plan=plan)
        with pytest.raises(ValueError, match="resnet18"):
            ckpt.restore_branch(str(tmp_path), "day",
                                scenario.branch_template(model),
                                plan=plan, model_name="resnet18")

    def test_missing_scenario_lists_available(self, vgg_cell, tmp_path):
        model, plan, params = vgg_cell
        ckpt.save_branch(str(tmp_path), "day",
                         scenario.split_params(params)[0],
                         model_name=model.cfg.name, plan=plan)
        with pytest.raises(FileNotFoundError, match="day"):
            ckpt.restore_branch(str(tmp_path), "night",
                                scenario.branch_template(model), plan=plan)

    def test_template_mismatch_is_geometry_error(self, vgg_cell, tmp_path):
        """Satellite 2: restoring onto the wrong template raises the
        same geometry-style error shape as PR 7's cache_geometry —
        names the missing/extra arrays, not a raw treedef crash."""
        model, plan, params = vgg_cell
        ckpt.save_branch(str(tmp_path), "day",
                         scenario.split_params(params)[0],
                         model_name=model.cfg.name, plan=plan)
        bare = deploy.compile_model(cnn.CNNConfig(
            name="vgg8", input_size=16,
            rebranch=ReBranchSpec(branch_enabled=False)))
        with pytest.raises(ValueError,
                           match="does not match the template"):
            ckpt.restore_branch(str(tmp_path), "day",
                                scenario.branch_template(bare), plan=plan)

    def test_shape_drift_is_geometry_error(self, vgg_cell, tmp_path):
        model, plan, params = vgg_cell
        ckpt.save_branch(str(tmp_path), "day",
                         scenario.split_params(params)[0],
                         model_name=model.cfg.name, plan=plan)
        wide = deploy.compile_model(cnn.CNNConfig(
            name="vgg8", input_size=16, num_classes=21))
        with pytest.raises(ValueError, match="geometry changed|does not "
                                             "match the template"):
            ckpt.restore_branch(str(tmp_path), "day",
                                scenario.branch_template(wide), plan=plan)

    def test_unsafe_scenario_name_rejected(self, vgg_cell, tmp_path):
        model, plan, params = vgg_cell
        with pytest.raises(ValueError, match="filesystem-safe"):
            ckpt.save_branch(str(tmp_path), "../escape",
                             scenario.split_params(params)[0],
                             model_name=model.cfg.name, plan=plan)

    def test_store_serves_from_checkpoint_source(self, vgg_cell, tmp_path):
        model, plan, params = vgg_cell
        branch = _perturb(scenario.split_params(params)[0], salt=7)
        ckpt.save_branch(str(tmp_path), "cold", branch,
                         model_name=model.cfg.name, plan=plan)
        store = ScenarioStore(model, plan, capacity=1)
        store.register("cold", ckpt_dir=str(tmp_path))
        got = store.get("cold")
        for a, b in zip(jax.tree.leaves(branch), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# scheduler: swap barrier + mixed-scenario isolation (LM decode)
# ---------------------------------------------------------------------------

class TestSchedulerSwap:
    def test_mixed_scenario_batched_decode_isolation(self, lm_cell):
        """r1 admitted under A, swap queued, r2 under B: both must be
        bit-identical to solo decodes under their own full params, the
        swap must apply only after r1 retires, and FIFO must hold."""
        model, _, pA = lm_cell
        brB = _perturb(rebranch.partition(pA)[0], salt=2)
        pB = rebranch.combine(brB, rebranch.partition(pA)[1])
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, model.cfg.vocab_size, size=7),
                   rng.integers(0, model.cfg.vocab_size, size=5)]
        pool = SlotPool(model, 2, MAX_LEN)
        b = ContinuousBatcher(model, _copy(pA), pool, scenario="a")
        r1 = b.submit(prompts[0], 6, scenario="a")
        b.step()                              # r1 admitted and decoding
        b.swap("b", brB)
        r2 = b.submit(prompts[1], 4, scenario="b")
        assert b.scenario == "a"              # barrier not applied yet
        b.drain(max_steps=100)
        assert b.swap_count == 1 and b.scenario == "b"
        assert r2.admit_step >= r1.finish_step    # waited for the barrier
        assert r1.tokens == _solo_decode(model, pA, prompts[0], 6)
        assert r2.tokens == _solo_decode(model, pB, prompts[1], 4)

    def test_submit_mismatched_scenario_requires_swap(self, lm_cell):
        model, _, pA = lm_cell
        b = ContinuousBatcher(model, _copy(pA), SlotPool(model, 1, MAX_LEN),
                              scenario="a")
        with pytest.raises(ValueError, match="queue tail runs"):
            b.submit([1, 2, 3], 2, scenario="b")

    def test_pending_scenario_tracks_queue_tail(self, lm_cell):
        model, _, pA = lm_cell
        b = ContinuousBatcher(model, _copy(pA), SlotPool(model, 1, MAX_LEN),
                              scenario="a")
        assert b.pending_scenario() == "a"
        b.swap("b", _perturb(rebranch.partition(pA)[0]))
        assert b.pending_scenario() == "b"
        assert b.scenario == "a"              # applies at a boundary only


# ---------------------------------------------------------------------------
# registry + front door integration
# ---------------------------------------------------------------------------

class TestRegistryScenarios:
    def test_entry_scenarios_seed_the_store_and_serve(self):
        """serve.load(id, scenario=...) over an entry-declared scenario
        must equal the branch combined onto the trunk by hand."""
        cfg = cnn.CNNConfig(name="vgg8", input_size=16)
        plan = PlacementPlan.from_config(cfg)

        def factory(model, plan):
            return _perturb(scenario.split_params(
                model.init(jax.random.PRNGKey(3)))[0], salt=4)

        serve.register(serve.ModelEntry(
            "vgg8-scn-test", config=lambda: cfg, plan=lambda c: plan,
            scenarios=(("alt", factory),)), override=True)
        assert serve.has_scenarios("vgg8-scn-test")
        model, _ = serve.compile_entry("vgg8-scn-test")
        params = model.init(jax.random.PRNGKey(0))
        srv = serve.load("vgg8-scn-test", params=_copy(params),
                         n_slots=2, scenario="alt")
        assert isinstance(srv, serve.CNNServer) and srv.scenario == "alt"
        store = serve.scenario_store("vgg8-scn-test")
        ref = rebranch.combine(store.get("alt"),
                               rebranch.partition(params)[1])
        x = np.random.default_rng(2).normal(
            size=(2, 16, 16, 3)).astype(np.float32)
        np.testing.assert_array_equal(
            srv.submit(x),
            np.asarray(jax.jit(model.forward)(ref, jnp.asarray(x))))

    def test_swap_scenario_without_store_raises(self, vgg_cell):
        model, _, params = vgg_cell
        srv = serve.CNNServer(model, _copy(params), n_slots=2)
        with pytest.raises(ValueError, match="no ScenarioStore"):
            srv.swap_scenario("x")

    def test_reregister_invalidates_cell_and_store(self):
        """Satellite 1: override-registering an id must drop BOTH the
        resident cell and its scenario store — the next compile_entry
        reflects the new config, and stale branches can't implant."""
        serve.register(serve.ModelEntry(
            "vgg8-rereg-test",
            config=lambda: cnn.CNNConfig(name="vgg8", input_size=16)),
            override=True)
        m1, _ = serve.compile_entry("vgg8-rereg-test")
        store1 = serve.scenario_store("vgg8-rereg-test")
        store1.register("s", branch=scenario.split_params(
            m1.init(jax.random.PRNGKey(0)))[0])
        assert m1.cfg.input_size == 16
        serve.register(serve.ModelEntry(
            "vgg8-rereg-test",
            config=lambda: cnn.CNNConfig(name="vgg8", input_size=32)),
            override=True)
        m2, _ = serve.compile_entry("vgg8-rereg-test")
        assert m2.cfg.input_size == 32 and m2 is not m1
        store2 = serve.scenario_store("vgg8-rereg-test")
        assert store2 is not store1 and "s" not in store2
